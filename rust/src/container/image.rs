//! Container image bundles: the on-disk product of a build.
//!
//! A bundle is a directory `<store>/<name>/<tag>/` holding:
//!   * `image.json`    — metadata: layers, env, workload binding, digest
//!   * `rootfs/`       — the payload: the AOT artifact files the contained
//!                        "framework" executes (the paper's framework
//!                        binaries), plus any %files copies
//!
//! The digest is a content hash over layer descriptions + payload bytes so
//! identical builds are reproducible and the registry can deduplicate.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::executor::{CopyPolicy, ExecPolicy};
use crate::util::json::Json;

/// One recorded build layer (a %post command and what it did).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layer {
    pub command: String,
    pub effect: String,
}

/// Parsed `image.json` + location of a built bundle.
#[derive(Debug, Clone)]
pub struct Image {
    pub name: String,
    pub tag: String,
    pub dir: PathBuf,
    pub base: String,
    pub layers: Vec<Layer>,
    pub env: BTreeMap<String, String>,
    /// Workload the contained framework stack runs.
    pub workload: Option<String>,
    /// Artifact variant baked into the image.
    pub variant: Option<String>,
    /// Execution policy of the contained framework runtime.
    pub policy: ExecPolicy,
    /// Whether the image contains the GPU userland (the paper: GPU images
    /// must carry the nvidia stack and be launched with --nv).
    pub gpu: bool,
    pub digest: String,
}

impl Image {
    /// `name:tag` reference.
    pub fn reference(&self) -> String {
        format!("{}:{}", self.name, self.tag)
    }

    pub fn rootfs(&self) -> PathBuf {
        self.dir.join("rootfs")
    }

    /// Write `image.json` into the bundle dir.
    pub fn save(&self) -> Result<()> {
        let mut j = Json::obj();
        let mut layers = Vec::new();
        for l in &self.layers {
            let mut lj = Json::obj();
            lj.set("command", Json::from(l.command.as_str()))
                .set("effect", Json::from(l.effect.as_str()));
            layers.push(lj);
        }
        let mut env = Json::obj();
        for (k, v) in &self.env {
            env.set(k, Json::from(v.as_str()));
        }
        j.set("name", Json::from(self.name.as_str()))
            .set("tag", Json::from(self.tag.as_str()))
            .set("base", Json::from(self.base.as_str()))
            .set("layers", Json::Arr(layers))
            .set("env", env)
            .set("gpu", Json::from(self.gpu))
            .set(
                "policy_copy",
                Json::from(match self.policy.copy {
                    CopyPolicy::HostRoundTrip => "host",
                    CopyPolicy::DeviceResident => "device",
                }),
            )
            .set(
                "policy_recompile",
                Json::from(self.policy.recompile_each_epoch),
            )
            .set("digest", Json::from(self.digest.as_str()));
        if let Some(w) = &self.workload {
            j.set("workload", Json::from(w.as_str()));
        }
        if let Some(v) = &self.variant {
            j.set("variant", Json::from(v.as_str()));
        }
        std::fs::create_dir_all(&self.dir)?;
        std::fs::write(self.dir.join("image.json"), j.to_string_pretty())
            .with_context(|| format!("writing image.json in {:?}", self.dir))?;
        Ok(())
    }

    /// Load a bundle from its directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Image> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("image.json"))
            .with_context(|| format!("no image.json in {dir:?}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("image.json: {e}"))?;
        let policy = ExecPolicy {
            copy: match j.get("policy_copy").as_str() {
                Some("device") => CopyPolicy::DeviceResident,
                _ => CopyPolicy::HostRoundTrip,
            },
            recompile_each_epoch: j.get("policy_recompile").as_bool().unwrap_or(false),
        };
        let layers = j
            .get("layers")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|l| Layer {
                command: l.get("command").as_str().unwrap_or("").to_string(),
                effect: l.get("effect").as_str().unwrap_or("").to_string(),
            })
            .collect();
        let mut env = BTreeMap::new();
        if let Some(e) = j.get("env").as_obj() {
            for (k, v) in e {
                env.insert(k.clone(), v.as_str().unwrap_or("").to_string());
            }
        }
        let need = |key: &str| -> Result<String> {
            Ok(j.get(key)
                .as_str()
                .ok_or_else(|| anyhow!("image.json missing {key}"))?
                .to_string())
        };
        Ok(Image {
            name: need("name")?,
            tag: need("tag")?,
            dir,
            base: need("base")?,
            layers,
            env,
            workload: j.get("workload").as_str().map(str::to_string),
            variant: j.get("variant").as_str().map(str::to_string),
            policy,
            gpu: j.get("gpu").as_bool().unwrap_or(false),
            digest: need("digest")?,
        })
    }

    /// Validate the bundle: payload files referenced by the manifest exist.
    pub fn verify(&self) -> Result<()> {
        if !self.rootfs().exists() {
            bail!("bundle {:?} has no rootfs", self.reference());
        }
        if self.variant.is_some() && !self.rootfs().join("manifest.json").exists() {
            bail!(
                "bundle {:?} declares a variant but carries no artifact manifest",
                self.reference()
            );
        }
        Ok(())
    }
}

/// FNV-1a over arbitrary byte chunks — a dependency-free content digest.
pub struct Digest {
    state: u64,
}

impl Digest {
    pub fn new() -> Digest {
        Digest {
            state: 0xcbf29ce484222325,
        }
    }

    pub fn update(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(0x100000001b3);
        }
        self
    }

    pub fn finish(&self) -> String {
        format!("fnv1a:{:016x}", self.state)
    }
}

impl Default for Digest {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("modak_image_tests").join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample(dir: PathBuf) -> Image {
        Image {
            name: "tensorflow".into(),
            tag: "2.1-cpu-hub".into(),
            dir,
            base: "ubuntu:18.04".into(),
            layers: vec![Layer {
                command: "modak-install framework=tensorflow".into(),
                effect: "bound variant fused_generic".into(),
            }],
            env: BTreeMap::from([("MODAK_TARGET".into(), "cpu".into())]),
            workload: Some("mnist_cnn".into()),
            variant: Some("fused_generic".into()),
            policy: ExecPolicy::host(),
            gpu: false,
            digest: "fnv1a:0000000000000000".into(),
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = tmpdir("roundtrip");
        let img = sample(dir.clone());
        img.save().unwrap();
        let back = Image::load(&dir).unwrap();
        assert_eq!(back.reference(), "tensorflow:2.1-cpu-hub");
        assert_eq!(back.variant.as_deref(), Some("fused_generic"));
        assert_eq!(back.policy, ExecPolicy::host());
        assert_eq!(back.layers.len(), 1);
        assert_eq!(back.env.get("MODAK_TARGET").unwrap(), "cpu");
        assert!(!back.gpu);
    }

    #[test]
    fn verify_requires_rootfs_and_manifest() {
        let dir = tmpdir("verify");
        let img = sample(dir.clone());
        img.save().unwrap();
        assert!(img.verify().is_err());
        std::fs::create_dir_all(img.rootfs()).unwrap();
        assert!(img.verify().is_err()); // variant declared, no manifest
        std::fs::write(img.rootfs().join("manifest.json"), "{}").unwrap();
        img.verify().unwrap();
    }

    #[test]
    fn digest_is_stable_and_sensitive() {
        let a = Digest::new().update(b"layer1").update(b"layer2").finish();
        let b = Digest::new().update(b"layer1").update(b"layer2").finish();
        let c = Digest::new().update(b"layer1").update(b"layerX").finish();
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.starts_with("fnv1a:"));
    }
}
