//! Singularity-like container subsystem (paper §IV-A, §V-B/C/D): definition
//! files, a fakeroot builder producing image bundles, and a runtime with
//! --nv GPU semantics. See DESIGN.md §1 for what this substitutes.

pub mod builder;
pub mod definition;
pub mod image;
pub mod runtime;

pub use builder::{BuildOptions, BuildPool, BuildStats, Builder};
pub use definition::{Bootstrap, DefinitionFile};
pub use image::{Digest, Image, Layer};
pub use runtime::{ContainerRun, ContainerRuntime, RunOptions, RunOutcome};
