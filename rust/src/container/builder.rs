//! The container builder: definition file -> image bundle (paper §V-B/C/D:
//! `singularity build --fakeroot`).
//!
//! %post commands get interpreted against a small vocabulary:
//!
//! * `modak-install framework=<fw> version=<v> variant=<artifact-variant>` —
//!   "installs the framework": copies the variant's AOT artifacts (plus
//!   init/update) into the bundle rootfs with a pruned manifest. This is the
//!   moment a real build compiles TensorFlow from source; ours stages the
//!   compiled stack the contained runtime will execute.
//! * `modak-policy copy=<host|device> [recompile=true]` — configures the
//!   contained framework runtime's execution policy.
//! * `apt-get ...` / `pip install ...` / anything else — recorded as opaque
//!   layers (they shape the digest, as layers do).
//!
//! Builds are reproducible: digest = hash(base, layers, payload bytes).

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::{Condvar, Mutex, PoisonError};

use anyhow::{anyhow, bail, Context, Result};

use crate::executor::{CopyPolicy, ExecPolicy};
use crate::runtime::{Manifest, VariantBinding};
use crate::util::dir_size;
use crate::util::json::Json;
use crate::util::lru::Lru;
use crate::util::sync::lock_or_recover;

use super::definition::DefinitionFile;
use super::image::{Digest, Image, Layer};

/// Builder options (the paper's build flags).
#[derive(Debug, Clone)]
pub struct BuildOptions {
    /// `--fakeroot`: required on the testbed because users may not run
    /// privileged builds (paper §V-B). Builds fail without it, as they do
    /// on an HPC system without the UID/GID mappings.
    pub fakeroot: bool,
}

impl Default for BuildOptions {
    fn default() -> Self {
        BuildOptions { fakeroot: true }
    }
}

/// Builds image bundles into a store directory.
pub struct Builder {
    store: PathBuf,
    /// Source of AOT artifacts ("the framework binaries").
    artifacts: Manifest,
}

impl Builder {
    pub fn new(store: impl AsRef<Path>, artifacts: Manifest) -> Builder {
        Builder {
            store: store.as_ref().to_path_buf(),
            artifacts,
        }
    }

    pub fn store(&self) -> &Path {
        &self.store
    }

    /// Build `def` into `<store>/<name>/<tag>/`.
    pub fn build(
        &self,
        name: &str,
        tag: &str,
        def: &DefinitionFile,
        opts: &BuildOptions,
    ) -> Result<Image> {
        if !opts.fakeroot {
            bail!(
                "unprivileged build requires --fakeroot (admin must add \
                 user-namespace UID/GID mappings, paper §V-B)"
            );
        }
        let dir = self.store.join(name).join(tag);
        let rootfs = dir.join("rootfs");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&rootfs)?;

        let gpu_base = def.from.to_ascii_lowercase().contains("nvidia")
            || def.from.to_ascii_lowercase().contains("cuda");
        let mut layers = vec![Layer {
            command: format!("FROM {}", def.from),
            effect: if gpu_base {
                "base OS with NVIDIA userland (cuda toolkit, cudnn)".into()
            } else {
                "base OS".into()
            },
        }];
        let mut policy = ExecPolicy::host();
        let mut workload = None;
        let mut variant = None;
        let mut digest = Digest::new();
        digest.update(def.from.as_bytes());

        // %files copies
        for (src, dst) in &def.files {
            let data = std::fs::read(src)
                .with_context(|| format!("%files source missing: {src}"))?;
            let dst_rel = dst.trim_start_matches('/');
            let dst_path = rootfs.join(dst_rel);
            if let Some(parent) = dst_path.parent() {
                std::fs::create_dir_all(parent)?;
            }
            digest.update(&data);
            std::fs::write(&dst_path, data)?;
            layers.push(Layer {
                command: format!("COPY {src} {dst}"),
                effect: format!("file staged at {dst_rel}"),
            });
        }

        // %post commands
        for cmd in &def.post {
            digest.update(cmd.as_bytes());
            let layer = if cmd.starts_with("modak-install") {
                let args = parse_kv(cmd);
                let v = args
                    .get("variant")
                    .ok_or_else(|| anyhow!("modak-install needs variant="))?;
                let w = args
                    .get("workload")
                    .map(String::as_str)
                    .unwrap_or("mnist_cnn");
                let bytes = self.stage_variant(&rootfs, w, v)?;
                digest.update(&bytes.to_le_bytes());
                workload = Some(w.to_string());
                variant = Some(v.to_string());
                Layer {
                    command: cmd.clone(),
                    effect: format!("staged {bytes} bytes of compiled artifacts for {w}/{v}"),
                }
            } else if cmd.starts_with("modak-policy") {
                let args = parse_kv(cmd);
                if let Some(c) = args.get("copy") {
                    policy.copy = match c.as_str() {
                        "host" => CopyPolicy::HostRoundTrip,
                        "device" => CopyPolicy::DeviceResident,
                        other => bail!("modak-policy copy={other:?} unknown"),
                    };
                }
                if args.get("recompile").map(String::as_str) == Some("true") {
                    policy.recompile_each_epoch = true;
                }
                Layer {
                    command: cmd.clone(),
                    effect: format!("runtime policy {policy:?}"),
                }
            } else {
                Layer {
                    command: cmd.clone(),
                    effect: "opaque build command".into(),
                }
            };
            layers.push(layer);
        }

        let image = Image {
            name: name.to_string(),
            tag: tag.to_string(),
            dir,
            base: def.from.clone(),
            layers,
            env: def.environment.clone(),
            workload,
            variant,
            policy,
            gpu: gpu_base,
            digest: digest.finish(),
        };
        image.save()?;
        image.verify().or_else(|e| {
            // images without a variant (pure base OS) have no manifest
            if image.variant.is_none() {
                Ok(())
            } else {
                Err(e)
            }
        })?;
        Ok(image)
    }

    /// Copy the artifacts a variant needs into the bundle rootfs, writing a
    /// pruned manifest restricted to that workload+variant. Returns bytes
    /// staged.
    fn stage_variant(&self, rootfs: &Path, workload: &str, variant: &str) -> Result<u64> {
        let wl = self.artifacts.workload(workload)?;
        let binding = wl
            .variants
            .get(variant)
            .ok_or_else(|| anyhow!("workload {workload} has no variant {variant:?}"))?;
        let mut ids: Vec<String> = vec![wl.init.clone(), wl.update.clone()];
        match binding {
            VariantBinding::Fused { step } => ids.push(step.clone()),
            VariantBinding::Staged { fwd, bwd } => {
                ids.extend(fwd.iter().cloned());
                ids.extend(bwd.iter().cloned());
            }
            VariantBinding::ThreeStage { fwd, bwd } => {
                ids.push(fwd.clone());
                ids.push(bwd.clone());
            }
        }

        let mut total = 0u64;
        for id in &ids {
            let src = self.artifacts.artifact_path(id)?;
            let data = std::fs::read(&src)
                .with_context(|| format!("artifact file {src:?}"))?;
            total += data.len() as u64;
            std::fs::write(rootfs.join(&self.artifacts.artifact(id)?.file), data)?;
        }

        // pruned manifest: same schema, only this workload + variant
        let full = std::fs::read_to_string(self.artifacts.dir.join("manifest.json"))?;
        let full = Json::parse(&full).map_err(|e| anyhow!("manifest: {e}"))?;
        let mut pruned_arts = Json::obj();
        if let Some(obj) = full.get("artifacts").as_obj() {
            for id in &ids {
                if let Some(a) = obj.get(id.as_str()) {
                    pruned_arts.set(id, a.clone());
                }
            }
        }
        let mut wl_entry = full.at(&["workloads", workload]).clone();
        if let Json::Obj(ref mut o) = wl_entry {
            let mut variants = Json::obj();
            if let Some(v) = full.at(&["workloads", workload, "variants", variant]).as_obj() {
                variants.set(variant, Json::Obj(v.clone()));
            }
            o.insert("variants".into(), variants);
        }
        let mut pruned = Json::obj();
        let mut wls = Json::obj();
        wls.set(workload, wl_entry);
        pruned
            .set("version", Json::from(1usize))
            .set("workloads", wls)
            .set("artifacts", pruned_arts);
        std::fs::write(rootfs.join("manifest.json"), pruned.to_string_pretty())?;
        Ok(total)
    }
}

/// Counters kept by the [`BuildPool`] (surfaced in the serve-batch summary
/// and asserted by the concurrency tests).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BuildStats {
    /// Successful builds executed by the pool (failed build attempts cache
    /// their error but produce no bundle and are not counted here).
    pub builds: usize,
    /// Requests satisfied without a build: an identical in-flight or
    /// completed build (digest-keyed), or a prebuilt bundle on disk.
    pub cache_hits: usize,
    /// Cold bundles garbage-collected from the store (capacity-bounded
    /// LRU; see `--store-cap-mb`).
    pub evictions: usize,
}

/// State of one digest-keyed build slot.
enum BuildSlot {
    /// A worker is building this definition right now; wait on the condvar.
    InFlight,
    /// Built earlier in this process; reuse the bundle.
    Done(Image),
    /// The build failed. Builds are deterministic (digest = content hash),
    /// so the failure is cached rather than retried.
    Failed(String),
}

struct PoolState {
    slots: HashMap<String, BuildSlot>,
    /// Builds currently executing (capped at `max_workers`).
    active: usize,
    stats: BuildStats,
    /// LRU bookkeeping over completed bundles (key = cache key, bytes =
    /// bundle dir size); bounds the store when a cap is set.
    lru: Lru<String>,
}

/// A concurrent front to the [`Builder`]: callers from many threads request
/// builds; identical definitions are built exactly once and concurrent
/// requests for the same image block on the in-flight build instead of
/// duplicating it. At most `max_workers` builds run at a time — extra
/// requests wait for a free worker slot.
///
/// The cache key is a content digest over (name, tag, rendered definition),
/// so any change to the definition invalidates the entry while identical
/// profiles coalesce.
pub struct BuildPool {
    builder: Builder,
    max_workers: usize,
    state: Mutex<PoolState>,
    cv: Condvar,
}

impl BuildPool {
    /// Open a pool over `store`. The digest cache index persisted by prior
    /// processes (`<store>/build_index.json`) is loaded on boot: entries
    /// whose bundles still verify on disk come back as completed slots, so
    /// a restarted service reuses prior builds instead of redoing them
    /// (ROADMAP: registry persistence).
    pub fn new(store: impl AsRef<Path>, artifacts: Manifest, max_workers: usize) -> BuildPool {
        Self::with_capacity(store, artifacts, max_workers, None)
    }

    /// [`Self::new`] with a byte cap on the store: after every successful
    /// build, bundles past the cap are garbage-collected coldest-first
    /// (their dirs deleted, their index entries dropped — an evicted image
    /// rebuilds on demand). Bundles restored from the persisted index are
    /// tracked too, so a restarted service still evicts its history.
    ///
    /// Known limit: eviction does not pin bundles referenced by queued or
    /// running jobs (the pool has no view of the scheduler). A cap sized
    /// well below the working set can evict a bundle between qsub and
    /// dispatch, failing that job at launch — size the cap generously;
    /// reference-pinned eviction is a ROADMAP follow-on.
    pub fn with_capacity(
        store: impl AsRef<Path>,
        artifacts: Manifest,
        max_workers: usize,
        store_cap_bytes: Option<u64>,
    ) -> BuildPool {
        let slots = load_index(store.as_ref());
        let mut lru = Lru::new(store_cap_bytes);
        // seed in sorted order so restart-time recency (and therefore any
        // later eviction tie-breaks) is deterministic
        let mut restored: Vec<(String, u64)> = slots
            .iter()
            .filter_map(|(key, slot)| match slot {
                BuildSlot::Done(img) => Some((key.clone(), dir_size(&img.dir))),
                _ => None,
            })
            .collect();
        restored.sort();
        for (key, bytes) in restored {
            lru.insert(key, bytes);
        }
        BuildPool {
            builder: Builder::new(store, artifacts),
            max_workers: max_workers.max(1),
            state: Mutex::new(PoolState {
                slots,
                active: 0,
                stats: BuildStats::default(),
                lru,
            }),
            cv: Condvar::new(),
        }
    }

    pub fn store(&self) -> &Path {
        self.builder.store()
    }

    /// The digest key a (name, tag, definition) triple caches under.
    pub fn cache_key(name: &str, tag: &str, def: &DefinitionFile) -> String {
        let mut d = Digest::new();
        d.update(name.as_bytes())
            .update(tag.as_bytes())
            .update(def.render().as_bytes());
        d.finish()
    }

    /// Build `def` into `<store>/<name>/<tag>/`, deduplicating against
    /// identical in-flight and completed builds.
    pub fn build_cached(&self, name: &str, tag: &str, def: &DefinitionFile) -> Result<Image> {
        enum Found {
            Done(Image),
            Failed(String),
            InFlight,
            Missing,
        }
        let key = Self::cache_key(name, tag, def);
        let mut st = lock_or_recover(&self.state);
        loop {
            let found = match st.slots.get(&key) {
                Some(BuildSlot::Done(img)) => Found::Done(img.clone()),
                Some(BuildSlot::Failed(e)) => Found::Failed(e.clone()),
                Some(BuildSlot::InFlight) => Found::InFlight,
                None => Found::Missing,
            };
            match found {
                Found::Done(img) => {
                    st.stats.cache_hits += 1;
                    // cross-batch observability (an atomic bump, no lock)
                    crate::obs::metrics::global().build_cache_hits.inc();
                    st.lru.touch(&key); // keep hot bundles off the GC list
                    return Ok(img);
                }
                Found::Failed(e) => {
                    st.stats.cache_hits += 1;
                    crate::obs::metrics::global().build_cache_hits.inc();
                    return Err(anyhow!("cached build failure for {name}:{tag}: {e}"));
                }
                Found::InFlight => {
                    st = self.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
                    continue;
                }
                Found::Missing => {}
            }
            if st.active >= self.max_workers {
                // all worker slots busy; wait, then re-check the cache first
                st = self.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
                continue;
            }
            st.slots.insert(key.clone(), BuildSlot::InFlight);
            st.active += 1;
            break;
        }
        drop(st);

        let result = self
            .builder
            .build(name, tag, def, &BuildOptions::default());

        let mut st = lock_or_recover(&self.state);
        st.active -= 1;
        let mut evicted_dirs: Vec<PathBuf> = Vec::new();
        let index_snapshot = match &result {
            Ok(img) => {
                st.stats.builds += 1;
                crate::obs::metrics::global().builds.inc();
                st.slots.insert(key.clone(), BuildSlot::Done(img.clone()));
                // store GC: track the new bundle, collect whatever the LRU
                // pushed past the cap (never the bundle just built)
                for ev in st.lru.insert(key, dir_size(&img.dir)) {
                    if let Some(BuildSlot::Done(old)) = st.slots.remove(&ev.key) {
                        evicted_dirs.push(old.dir);
                    }
                    st.stats.evictions += 1;
                }
                // append-on-build: serialize the index under the lock
                // (evicted entries are already gone from the slots)...
                Some(render_index(&st))
            }
            Err(e) => {
                st.slots.insert(key, BuildSlot::Failed(format!("{e:#}")));
                None
            }
        };
        drop(st);
        self.cv.notify_all();
        // ...but hit the disk outside it, so concurrent builders never
        // queue behind file I/O. Concurrent writers last-write-wins on a
        // whole-file write; a momentarily stale index only costs a rebuild
        // after a restart, never correctness.
        for dir in evicted_dirs {
            let _ = std::fs::remove_dir_all(&dir);
        }
        if let Some(text) = index_snapshot {
            let path = index_path(self.builder.store());
            if let Some(dir) = path.parent() {
                let _ = std::fs::create_dir_all(dir);
            }
            if let Err(e) = std::fs::write(&path, text) {
                eprintln!("build pool: persisting digest index failed: {e}");
            }
        }
        result
    }

    /// Record a cache hit that bypassed the pool entirely (a prebuilt
    /// bundle found on disk by the registry).
    pub fn note_prebuilt_hit(&self) {
        lock_or_recover(&self.state).stats.cache_hits += 1;
        crate::obs::metrics::global().build_cache_hits.inc();
    }

    /// Reference-pin every cached bundle for image `reference`
    /// (`name:tag`) against store GC: a queued/running job still points at
    /// it, so `--store-cap-mb` pressure must never evict it (refcounted;
    /// pin after `build_cached`/`ensure_built` returns, unpin when the job
    /// is terminal).
    pub fn pin_image(&self, reference: &str) {
        let mut st = lock_or_recover(&self.state);
        for key in bundle_keys(&st, reference) {
            st.lru.pin(&key);
        }
    }

    /// Drop one pin reference on every cached bundle for `reference`.
    pub fn unpin_image(&self, reference: &str) {
        let mut st = lock_or_recover(&self.state);
        for key in bundle_keys(&st, reference) {
            st.lru.unpin(&key);
        }
    }

    pub fn stats(&self) -> BuildStats {
        lock_or_recover(&self.state).stats.clone()
    }
}

fn index_path(store: &Path) -> PathBuf {
    store.join("build_index.json")
}

/// Cache keys of every completed bundle for image `reference` (`name:tag`)
/// — the one matching rule behind pin/unpin.
fn bundle_keys(st: &PoolState, reference: &str) -> Vec<String> {
    st.slots
        .iter()
        .filter_map(|(key, slot)| match slot {
            BuildSlot::Done(img) if img.reference() == reference => Some(key.clone()),
            _ => None,
        })
        .collect()
}

/// Serialize the digest -> bundle index (successful builds only: failures
/// are deterministic for a given definition but may be environmental —
/// missing artifacts — so a fresh process retries them).
fn render_index(st: &PoolState) -> String {
    let mut entries = Vec::new();
    for (key, slot) in &st.slots {
        if let BuildSlot::Done(img) = slot {
            let mut e = Json::obj();
            e.set("key", Json::from(key.as_str()))
                .set("name", Json::from(img.name.as_str()))
                .set("tag", Json::from(img.tag.as_str()))
                .set("dir", Json::from(img.dir.to_string_lossy().as_ref()));
            entries.push(e);
        }
    }
    let mut j = Json::obj();
    j.set("entries", Json::Arr(entries));
    j.to_string_pretty()
}

/// Load the persisted digest index: only entries whose bundle still loads
/// (and verifies) from disk are trusted; the rest are silently dropped and
/// will rebuild on demand.
fn load_index(store: &Path) -> HashMap<String, BuildSlot> {
    let mut slots = HashMap::new();
    let Ok(text) = std::fs::read_to_string(index_path(store)) else {
        return slots;
    };
    let Ok(j) = Json::parse(&text) else { return slots };
    for e in j.get("entries").as_arr().unwrap_or(&[]) {
        let (Some(key), Some(dir)) = (e.get("key").as_str(), e.get("dir").as_str()) else {
            continue;
        };
        if let Ok(img) = Image::load(Path::new(dir)) {
            slots.insert(key.to_string(), BuildSlot::Done(img));
        }
    }
    slots
}

fn parse_kv(cmd: &str) -> BTreeMap<String, String> {
    cmd.split_whitespace()
        .filter_map(|tok| tok.split_once('='))
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::definition::Bootstrap;

    fn test_manifest() -> Option<Manifest> {
        Manifest::load("artifacts").ok()
    }

    fn store(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("modak_builder_tests").join(name);
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn build_stages_variant_artifacts() {
        let Some(m) = test_manifest() else {
            eprintln!("skipping (run `make artifacts`)");
            return;
        };
        let builder = Builder::new(store("stage"), m);
        let mut def = DefinitionFile::new(Bootstrap::Library, "ubuntu:18.04");
        def.post.push("apt-get install -y python3".into());
        def.post.push(
            "modak-install framework=tensorflow version=2.1 workload=mnist_cnn variant=fused_ref"
                .into(),
        );
        def.post.push("modak-policy copy=host".into());
        let img = builder
            .build("tensorflow", "2.1-cpu-src", &def, &BuildOptions::default())
            .unwrap();
        assert_eq!(img.variant.as_deref(), Some("fused_ref"));
        assert!(img.rootfs().join("manifest.json").exists());
        // the pruned manifest must load + validate against the bundle dir
        let pruned = Manifest::load(img.rootfs()).unwrap();
        assert!(pruned.workload("mnist_cnn").is_ok());
        assert_eq!(pruned.workload("mnist_cnn").unwrap().variants.len(), 1);
        assert!(!img.gpu);
        assert_eq!(img.layers.len(), 4); // FROM + 3 post commands
    }

    #[test]
    fn build_without_fakeroot_fails() {
        let Some(m) = test_manifest() else { return };
        let builder = Builder::new(store("nofakeroot"), m);
        let def = DefinitionFile::new(Bootstrap::Library, "ubuntu:18.04");
        let err = builder
            .build("base", "os", &def, &BuildOptions { fakeroot: false })
            .unwrap_err();
        assert!(err.to_string().contains("fakeroot"));
    }

    #[test]
    fn nvidia_base_marks_gpu() {
        let Some(m) = test_manifest() else { return };
        let builder = Builder::new(store("gpu"), m);
        let mut def = DefinitionFile::new(
            Bootstrap::Docker,
            "nvidia/cuda:10.1-cudnn7-devel-ubuntu18.04",
        );
        def.post.push(
            "modak-install framework=tensorflow version=2.1 workload=resnet50s variant=threestage_ref"
                .into(),
        );
        let img = builder
            .build("tensorflow", "2.1-gpu-hub", &def, &BuildOptions::default())
            .unwrap();
        assert!(img.gpu);
    }

    #[test]
    fn identical_builds_share_digest() {
        let Some(m) = test_manifest() else { return };
        let builder = Builder::new(store("digest"), m);
        let mut def = DefinitionFile::new(Bootstrap::Library, "ubuntu:18.04");
        def.post
            .push("modak-install workload=mnist_cnn variant=staged_ref".into());
        let a = builder
            .build("pytorch", "a", &def, &BuildOptions::default())
            .unwrap();
        let b = builder
            .build("pytorch", "b", &def, &BuildOptions::default())
            .unwrap();
        assert_eq!(a.digest, b.digest);
        def.post.push("pip install extras".into());
        let c = builder
            .build("pytorch", "c", &def, &BuildOptions::default())
            .unwrap();
        assert_ne!(a.digest, c.digest);
    }

    /// An empty manifest: enough to build definitions that stage no
    /// artifacts (pure base-OS images), so the pool's concurrency behaviour
    /// is testable without `make artifacts`.
    fn empty_manifest() -> Manifest {
        Manifest {
            dir: PathBuf::from("artifacts-not-needed"),
            workloads: Default::default(),
            artifacts: Default::default(),
        }
    }

    fn base_def() -> DefinitionFile {
        let mut def = DefinitionFile::new(Bootstrap::Library, "ubuntu:18.04");
        def.post.push("apt-get install -y python3".into());
        def
    }

    #[test]
    fn pool_coalesces_identical_concurrent_builds() {
        use std::sync::Arc;
        let pool = Arc::new(BuildPool::new(store("pool_dedup"), empty_manifest(), 2));
        let def = base_def();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let pool = Arc::clone(&pool);
                let def = def.clone();
                std::thread::spawn(move || pool.build_cached("base", "os", &def))
            })
            .collect();
        let images: Vec<Image> = handles
            .into_iter()
            .map(|h| h.join().unwrap().unwrap())
            .collect();
        // same bundle for everyone: one build, three digest-keyed hits
        for img in &images[1..] {
            assert_eq!(img.digest, images[0].digest);
            assert_eq!(img.dir, images[0].dir);
        }
        let stats = pool.stats();
        assert_eq!(stats.builds, 1, "{stats:?}");
        assert_eq!(stats.cache_hits, 3, "{stats:?}");
    }

    #[test]
    fn pool_distinguishes_definitions_by_digest() {
        let pool = BuildPool::new(store("pool_keys"), empty_manifest(), 1);
        let a = pool.build_cached("base", "a", &base_def()).unwrap();
        let mut other = base_def();
        other.post.push("pip install extras".into());
        let b = pool.build_cached("base", "b", &other).unwrap();
        assert_ne!(a.digest, b.digest);
        let stats = pool.stats();
        assert_eq!(stats.builds, 2);
        assert_eq!(stats.cache_hits, 0);
    }

    /// Satellite: the digest cache index persists under the store — a
    /// fresh pool (a "restarted process") reuses the prior build, and a
    /// stale entry whose bundle vanished is dropped, not trusted.
    #[test]
    fn digest_index_round_trips_across_pool_restarts() {
        let dir = store("pool_persist");
        let first = BuildPool::new(&dir, empty_manifest(), 2);
        let img = first.build_cached("base", "os", &base_def()).unwrap();
        assert_eq!(first.stats().builds, 1);
        assert!(dir.join("build_index.json").exists(), "index written on build");
        drop(first);
        let second = BuildPool::new(&dir, empty_manifest(), 2);
        let again = second.build_cached("base", "os", &base_def()).unwrap();
        assert_eq!(again.digest, img.digest);
        assert_eq!(again.dir, img.dir);
        let stats = second.stats();
        assert_eq!(stats.builds, 0, "{stats:?}");
        assert_eq!(stats.cache_hits, 1, "{stats:?}");
        // stale entry: bundle deleted out from under the index
        std::fs::remove_dir_all(&img.dir).unwrap();
        let third = BuildPool::new(&dir, empty_manifest(), 2);
        let rebuilt = third.build_cached("base", "os", &base_def()).unwrap();
        assert_eq!(third.stats().builds, 1, "stale entry must rebuild");
        assert_eq!(rebuilt.digest, img.digest);
    }

    /// Satellite (ROADMAP: registry eviction): a capacity-bounded store
    /// garbage-collects the coldest bundle — its dir is deleted and its
    /// `build_index.json` entry dropped — and an evicted image rebuilds on
    /// demand in a fresh pool.
    #[test]
    fn store_cap_evicts_cold_bundles_and_honours_the_index() {
        let dir = store("pool_evict");
        // each base-OS bundle is a small dir; cap the store at one bundle
        let probe = BuildPool::new(&dir, empty_manifest(), 1);
        let first = probe.build_cached("base", "a", &base_def()).unwrap();
        let bundle_bytes = dir_size(&first.dir).max(1);
        drop(probe);
        let _ = std::fs::remove_dir_all(&dir);

        let pool = BuildPool::with_capacity(
            &dir,
            empty_manifest(),
            1,
            Some(bundle_bytes + bundle_bytes / 2), // fits 1, not 2
        );
        let a = pool.build_cached("base", "a", &base_def()).unwrap();
        let mut def_b = base_def();
        def_b.post.push("pip install extras".into());
        let b = pool.build_cached("base", "b", &def_b).unwrap();
        let stats = pool.stats();
        assert_eq!(stats.builds, 2, "{stats:?}");
        assert_eq!(stats.evictions, 1, "a evicted to fit b: {stats:?}");
        assert!(!a.dir.exists(), "evicted bundle deleted from the store");
        assert!(b.dir.exists(), "freshly built bundle kept");
        // the persisted index honours the eviction: the evicted bundle's
        // entry is gone, the survivor's remains
        let text = std::fs::read_to_string(index_path(&dir)).unwrap();
        assert!(
            !text.contains(a.dir.to_string_lossy().as_ref()),
            "index still references the evicted bundle: {text}"
        );
        assert!(text.contains(b.dir.to_string_lossy().as_ref()), "{text}");
        // a restarted pool rebuilds the evicted image on demand
        let restarted = BuildPool::with_capacity(&dir, empty_manifest(), 1, None);
        let again = restarted.build_cached("base", "a", &base_def()).unwrap();
        assert_eq!(restarted.stats().builds, 1, "evicted image rebuilt");
        assert_eq!(again.digest, a.digest);
    }

    /// Satellite acceptance (reference-pinned eviction): a bundle pinned
    /// by a queued/running job SURVIVES `--store-cap-mb` pressure — the
    /// GC takes unpinned bundles (or nothing) instead — and becomes
    /// ordinary LRU prey again once unpinned.
    #[test]
    fn pinned_bundle_survives_store_cap_pressure() {
        let dir = store("pool_pinned");
        let probe = BuildPool::new(&dir, empty_manifest(), 1);
        let first = probe.build_cached("base", "a", &base_def()).unwrap();
        let bundle_bytes = dir_size(&first.dir).max(1);
        drop(probe);
        let _ = std::fs::remove_dir_all(&dir);

        let pool = BuildPool::with_capacity(
            &dir,
            empty_manifest(),
            1,
            Some(bundle_bytes + bundle_bytes / 2), // fits 1 bundle, not 2
        );
        let a = pool.build_cached("base", "a", &base_def()).unwrap();
        pool.pin_image(&a.reference()); // a queued job references base:a
        let mut def_b = base_def();
        def_b.post.push("pip install extras".into());
        let b = pool.build_cached("base", "b", &def_b).unwrap();
        // cap pressure, but the only candidate is pinned: nothing evicted
        let stats = pool.stats();
        assert_eq!(stats.evictions, 0, "pinned bundle must survive: {stats:?}");
        assert!(a.dir.exists(), "pinned bundle still on disk");
        assert!(b.dir.exists());
        // the job finished: unpin, and the next build may evict `a`
        pool.unpin_image(&a.reference());
        let mut def_c = base_def();
        def_c.post.push("pip install more-extras".into());
        let c = pool.build_cached("base", "c", &def_c).unwrap();
        let stats = pool.stats();
        assert!(stats.evictions >= 1, "unpinned bundles are prey: {stats:?}");
        assert!(!a.dir.exists(), "coldest unpinned bundle evicted");
        assert!(c.dir.exists());
    }

    #[test]
    fn pool_caches_failures_deterministically() {
        let pool = BuildPool::new(store("pool_fail"), empty_manifest(), 2);
        let mut def = base_def();
        // references a workload the empty manifest does not have
        def.post
            .push("modak-install workload=mnist_cnn variant=fused_ref".into());
        assert!(pool.build_cached("x", "y", &def).is_err());
        assert!(pool.build_cached("x", "y", &def).is_err());
        let stats = pool.stats();
        assert_eq!(stats.builds, 0);
        assert_eq!(stats.cache_hits, 1); // second call hit the cached failure
    }

    #[test]
    fn unknown_variant_fails_build() {
        let Some(m) = test_manifest() else { return };
        let builder = Builder::new(store("badvariant"), m);
        let mut def = DefinitionFile::new(Bootstrap::Library, "ubuntu:18.04");
        def.post
            .push("modak-install workload=mnist_cnn variant=cuda_magic".into());
        assert!(builder
            .build("x", "y", &def, &BuildOptions::default())
            .is_err());
    }
}
