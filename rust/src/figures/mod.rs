//! Figure/table regeneration harness (DESIGN.md §3): every table and figure
//! in the paper's evaluation, reproduced end-to-end through the full stack —
//! registry -> container build -> Torque qsub -> node -> PJRT training ->
//! report.
//!
//! Timing protocol: benches boot a single node of the relevant class so job
//! timings never contend for the host's one core; the paper's Y axis is
//! reproduced as `first_epoch + (N-1) * steady_epoch` extrapolated to the
//! paper's epoch count (MNIST N=12; ResNet reports sec/epoch), with
//! container/session startup (artifact compilation) excluded — the paper
//! also excludes container startup and notes first-epoch overhead
//! separately. The XLA profile's per-epoch recompiles land *inside* epochs,
//! which is the effect Fig 5 measures.

use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::frameworks::Target;
use crate::metrics::{speedup_pct, FigureReport};
use crate::perfmodel::{Features, PerfModel, Record};
use crate::registry::RegistryHandle;
use crate::runtime::Manifest;
use crate::scheduler::{JobScript, JobState, Payload, Resources, TorqueServer};
use crate::trainer::TrainConfig;

/// How a figure's jobs are sized.
#[derive(Debug, Clone)]
pub struct FigureConfig {
    pub epochs: usize,
    pub steps_per_epoch: usize,
    /// Extrapolate the reported wallclock to this many epochs (None =
    /// report sec/epoch instead, ResNet-style).
    pub scale_to_epochs: Option<usize>,
    pub lr: f32,
    pub seed: i32,
}

impl FigureConfig {
    /// MNIST figures: measure 3 epochs, report the paper's 12-epoch number.
    pub fn mnist() -> FigureConfig {
        FigureConfig {
            epochs: 3,
            steps_per_epoch: 4,
            scale_to_epochs: Some(12),
            lr: 0.05,
            seed: 0,
        }
    }

    /// Graph-compiler figure: the paper's full-length epochs matter here —
    /// the XLA verdict *is* the compile/compute ratio, so short epochs
    /// would overstate the penalty (see EXPERIMENTS.md).
    pub fn mnist_compilers() -> FigureConfig {
        FigureConfig {
            steps_per_epoch: 30,
            ..FigureConfig::mnist()
        }
    }

    /// ResNet figures: average sec/epoch, steady state (paper protocol:
    /// 3 epochs; we run 4 with longer epochs so the 1-core host's timing
    /// noise stays well under the effects being measured).
    pub fn resnet() -> FigureConfig {
        FigureConfig {
            epochs: 4,
            steps_per_epoch: 8,
            scale_to_epochs: None,
            lr: 0.02,
            seed: 0,
        }
    }

    fn train_config(&self) -> TrainConfig {
        TrainConfig {
            epochs: self.epochs,
            steps_per_epoch: self.steps_per_epoch,
            seed: self.seed as u64,
        }
    }
}

/// Outcome of one container benchmark run.
#[derive(Debug, Clone)]
pub struct BenchRun {
    pub label: String,
    pub tag: String,
    /// The figure metric (extrapolated total or sec/epoch).
    pub metric_secs: f64,
    pub first_epoch_secs: f64,
    pub steady_epoch_secs: f64,
    pub final_loss: f64,
    pub dispatches: u64,
    pub bytes_host: u64,
    pub compile_secs: f64,
}

/// Shared context for running figures.
pub struct Harness<'a> {
    pub manifest: &'a Manifest,
    /// Shared registry + build pool (a cheap clone of the caller's handle).
    pub registry: RegistryHandle,
    /// When set, every run is recorded into the performance model.
    pub model: Option<&'a mut PerfModel>,
    /// Print progress lines.
    pub verbose: bool,
}

impl<'a> Harness<'a> {
    pub fn new(manifest: &'a Manifest, registry: &RegistryHandle) -> Harness<'a> {
        Harness {
            manifest,
            registry: registry.clone(),
            model: None,
            verbose: true,
        }
    }

    /// Run one container benchmark through the full scheduler stack.
    pub fn run_container(&mut self, tag: &str, cfg: &FigureConfig) -> Result<BenchRun> {
        let profile = self.registry.profile(tag)?;
        let image = self.registry.ensure_built(tag)?;
        if self.verbose {
            eprintln!("[bench] {tag}: image {} ({})", image.reference(), image.digest);
        }

        // one node of the right class: exclusive timing on a 1-core host
        let mut server = match profile.target {
            Target::Cpu => TorqueServer::boot(1, 0),
            Target::GpuSim => TorqueServer::boot(0, 1),
        };
        server.register_image(tag, image.dir.clone());
        let script = JobScript {
            name: format!("bench-{}", profile.label().to_lowercase()),
            queue: "batch".into(),
            resources: Resources {
                nodes: 1,
                gpus: if profile.target == Target::GpuSim { 1 } else { 0 },
                slots: 1,
                walltime: Duration::from_secs(4 * 3600),
            },
            payload: Payload {
                image: tag.to_string(),
                epochs: cfg.epochs,
                steps_per_epoch: cfg.steps_per_epoch,
                lr: cfg.lr,
                seed: cfg.seed,
                nv: profile.target == Target::GpuSim,
                dataset: None,
            },
            predicted_secs: None,
        };
        let id = server.qsub(script)?;
        server.wait(id)?;
        let rec = server.job(id)?;
        let JobState::Completed { run, .. } = &rec.state else {
            return Err(anyhow!(
                "bench job for {tag} did not complete: {:?}",
                rec.state
            ));
        };

        let report = &run.report;
        let first = report.epoch_secs[0];
        // min over post-warmup epochs: this host is a shared VM with
        // visible CPU-steal spikes; the paper's testbed was exclusive.
        // min-of-epochs is the standard interference-robust estimator.
        let steady = report.epoch_secs[1..]
            .iter()
            .copied()
            .fold(f64::INFINITY, f64::min)
            .min(report.epoch_secs[0]);
        let metric = match cfg.scale_to_epochs {
            Some(n) => first + steady * (n.saturating_sub(1)) as f64,
            None => steady,
        };
        let out = BenchRun {
            label: profile.label(),
            tag: tag.to_string(),
            metric_secs: metric,
            first_epoch_secs: first,
            steady_epoch_secs: steady,
            final_loss: report.final_loss(),
            dispatches: run.dispatches,
            bytes_host: run.bytes_h2d + run.bytes_d2h,
            compile_secs: run.compile_secs,
        };
        if self.verbose {
            eprintln!(
                "[bench] {tag}: metric {:.2}s (first {:.2}s steady {:.2}s loss {:.3})",
                out.metric_secs, first, steady, out.final_loss
            );
        }
        if let Some(model) = self.model.as_deref_mut() {
            let wl = self.manifest.workload(profile.workload)?;
            model.observe(Record {
                image: tag.to_string(),
                workload: profile.workload.to_string(),
                features: Features::derive(&profile, wl, &cfg.train_config()),
                measured_secs: report.total_secs,
            });
        }
        Ok(out)
    }

    fn run_set(
        &mut self,
        report: &mut FigureReport,
        tags: &[&str],
        cfg: &FigureConfig,
    ) -> Result<Vec<BenchRun>> {
        let mut runs = Vec::new();
        for tag in tags {
            let run = self.run_container(tag, cfg)?;
            report.push(run.label.clone(), run.metric_secs);
            runs.push(run);
        }
        Ok(runs)
    }

    // ---- Table I -----------------------------------------------------------

    /// Table I: source matrix of AI framework containers.
    pub fn table1(&mut self) -> FigureReport {
        let mut rep = FigureReport::new(
            "table1",
            "Source of AI framework containers",
            "availability (1 = packaged)",
        );
        for (fw, ver, hub, pip, opt) in self.registry.table1() {
            rep.push(
                format!(
                    "{fw} {ver} [{}{}{}]",
                    if hub { "Hub " } else { "" },
                    if pip { "pip " } else { "" },
                    if opt { "opt-build" } else { "" }
                ),
                (hub as u8 + pip as u8 + opt as u8) as f64,
            );
        }
        rep.check(
            "TensorFlow, PyTorch, MXNet, CNTK all packaged (paper Table I)",
            ["tensorflow", "pytorch", "mxnet", "cntk"].iter().all(|fw| {
                self.registry.table1().iter().any(|(f, ..)| f == fw)
            }),
        );
        rep
    }

    // ---- Fig 3 -------------------------------------------------------------

    /// Fig 3: DockerHub containers, MNIST CNN training on CPU.
    pub fn fig3(&mut self, cfg: &FigureConfig) -> Result<FigureReport> {
        let mut rep = FigureReport::new(
            "fig3",
            "Performance of DockerHub AI framework containers (MNIST CNN, CPU)",
            metric_name(cfg),
        );
        self.run_set(
            &mut rep,
            &[
                "tensorflow:1.4-cpu-hub",
                "tensorflow:2.1-cpu-hub",
                "pytorch:1.14-cpu-hub",
                "mxnet:2.0-cpu-hub",
                "cntk:2.7-cpu-hub",
            ],
            cfg,
        )?;
        let tf14 = rep.get("TF1.4").unwrap();
        let tf21 = rep.get("TF2.1").unwrap();
        let pt = rep.get("Pytorch").unwrap();
        let mx = rep.get("Mxnet").unwrap();
        let cntk = rep.get("Cntk").unwrap();
        rep.check(
            format!(
                "TF2.1 substantially faster than TF1.4 (paper ~54%; measured {:.0}%)",
                speedup_pct(tf14, tf21)
            ),
            tf21 < 0.85 * tf14,
        );
        // The paper finds TF1.4 ~= PyTorch ~= MXNet. Our eager profiles
        // (PyTorch/MXNet, device-resident) agree tightly; the TF1.4
        // session profile pays steeper feed-dict host copies than the real
        // TF1.4 did, so the band is wider (documented in EXPERIMENTS.md).
        rep.check(
            "PyTorch and MXNet perform similarly (within 25%)",
            (pt - mx).abs() < 0.25 * pt.max(mx),
        );
        rep.check(
            "TF1.4 in the same band as the eager frameworks (within 2x), \
             nowhere near the CNTK outlier",
            tf14 < 2.0 * pt.max(mx) && tf14 < 0.5 * cntk,
        );
        rep.check(
            format!(
                "CNTK is a far outlier (paper: lack of CPU optimisations; measured {:.1}x TF2.1)",
                cntk / tf21
            ),
            cntk > 3.0 * tf21,
        );
        Ok(rep)
    }

    // ---- Fig 4 -------------------------------------------------------------

    /// Fig 4 left: custom source builds vs DockerHub, MNIST CNN on CPU.
    pub fn fig4_left(&mut self, cfg: &FigureConfig) -> Result<FigureReport> {
        let mut rep = FigureReport::new(
            "fig4_left",
            "Custom source builds vs DockerHub (MNIST CNN, CPU)",
            metric_name(cfg),
        );
        self.run_set(
            &mut rep,
            &[
                "tensorflow:2.1-cpu-hub",
                "tensorflow:2.1-cpu-src",
                "pytorch:1.14-cpu-hub",
                "pytorch:1.14-cpu-src",
            ],
            cfg,
        )?;
        let tf_hub = rep.get("TF2.1").unwrap();
        let tf_src = rep.get("TF2.1-src").unwrap();
        let pt_hub = rep.get("Pytorch").unwrap();
        let pt_src = rep.get("Pytorch-src").unwrap();
        rep.check(
            format!(
                "TF2.1 source build faster than hub (paper 4%; measured {:.0}%)",
                speedup_pct(tf_hub, tf_src)
            ),
            tf_src < tf_hub,
        );
        rep.check(
            format!(
                "PyTorch source build faster than hub (paper 17%; measured {:.0}%)",
                speedup_pct(pt_hub, pt_src)
            ),
            pt_src < pt_hub,
        );
        rep.check(
            "PyTorch gains at least as much from the source build as TF",
            speedup_pct(pt_hub, pt_src) >= speedup_pct(tf_hub, tf_src) - 5.0,
        );
        Ok(rep)
    }

    /// Fig 4 right: ResNet50 training on the gpu-sim nodes, hub vs src.
    pub fn fig4_right(&mut self, cfg: &FigureConfig) -> Result<FigureReport> {
        let mut rep = FigureReport::new(
            "fig4_right",
            "Custom builds vs DockerHub (ResNet50, gpu-sim)",
            metric_name(cfg),
        );
        self.run_set(
            &mut rep,
            &[
                "tensorflow:2.1-gpu-hub",
                "tensorflow:2.1-gpu-src",
                "pytorch:1.14-gpu-hub",
                "pytorch:1.14-gpu-src",
                "mxnet:2.0-gpu-hub",
            ],
            cfg,
        )?;
        let tf_hub = rep.get("TF2.1").unwrap();
        let tf_src = rep.get("TF2.1-src").unwrap();
        let pt_hub = rep.get("Pytorch").unwrap();
        let pt_src = rep.get("Pytorch-src").unwrap();
        let mx = rep.get("Mxnet").unwrap();
        rep.check(
            format!(
                "source builds give only slight gains in the compute-bound regime \
                 (paper ~2%; measured TF {:.0}%, PT {:.0}%)",
                speedup_pct(tf_hub, tf_src),
                speedup_pct(pt_hub, pt_src)
            ),
            (speedup_pct(tf_hub, tf_src)).abs() < 25.0 && (speedup_pct(pt_hub, pt_src)).abs() < 25.0,
        );
        rep.check(
            "MXNet performs similarly to the others",
            (mx - tf_hub).abs() < 0.35 * tf_hub,
        );
        Ok(rep)
    }

    // ---- Fig 5 -------------------------------------------------------------

    /// Fig 5 left: graph compilers on CPU — XLA slows MNIST down, nGraph
    /// speeds it up.
    pub fn fig5_left(&mut self, cfg: &FigureConfig) -> Result<FigureReport> {
        let mut rep = FigureReport::new(
            "fig5_left",
            "Graph compilers (MNIST CNN, CPU): XLA vs nGraph",
            metric_name(cfg),
        );
        self.run_set(
            &mut rep,
            &[
                "tensorflow:2.1-cpu-hub",
                "tensorflow:2.1-cpu-src-xla",
                "tensorflow:1.4-cpu-hub",
                "tensorflow:1.4-cpu-src-ngraph",
            ],
            cfg,
        )?;
        let tf21 = rep.get("TF2.1").unwrap();
        let xla = rep.get("TF2.1-src-XLA").unwrap();
        let tf14 = rep.get("TF1.4").unwrap();
        let ngraph = rep.get("TF1.4-src-NGRAPH").unwrap();
        rep.check(
            format!(
                "XLA *degrades* CPU MNIST training (paper ~30% loss from recompilation; \
                 measured {:.0}% slower)",
                -speedup_pct(tf21, xla)
            ),
            xla > tf21,
        );
        rep.check(
            format!(
                "nGraph speeds up TF1.4 (paper 30%; measured {:.0}%)",
                speedup_pct(tf14, ngraph)
            ),
            ngraph < 0.85 * tf14,
        );
        Ok(rep)
    }

    /// Fig 5 right: TF2.1 + XLA on the gpu-sim ResNet50 — the sign flips.
    pub fn fig5_right(&mut self, cfg: &FigureConfig) -> Result<FigureReport> {
        let mut rep = FigureReport::new(
            "fig5_right",
            "TF2.1 + XLA (ResNet50, gpu-sim): compiler helps here",
            metric_name(cfg),
        );
        self.run_set(
            &mut rep,
            &["tensorflow:2.1-gpu-src", "tensorflow:2.1-gpu-src-xla"],
            cfg,
        )?;
        let base = rep.get("TF2.1-src").unwrap();
        let xla = rep.get("TF2.1-src-XLA").unwrap();
        rep.check(
            format!(
                "XLA *improves* ResNet50 (paper 9%; measured {:.0}%)",
                speedup_pct(base, xla)
            ),
            xla < base,
        );
        Ok(rep)
    }
}

fn metric_name(cfg: &FigureConfig) -> &'static str {
    match cfg.scale_to_epochs {
        Some(12) => "wallclock seconds for 12 epochs (first + 11 x steady)",
        Some(_) => "extrapolated wallclock seconds",
        None => "seconds per epoch (steady state)",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure_configs_follow_paper_protocol() {
        let m = FigureConfig::mnist();
        assert_eq!(m.scale_to_epochs, Some(12));
        let r = FigureConfig::resnet();
        assert_eq!(r.epochs, 4);
        assert!(r.scale_to_epochs.is_none());
        assert!(FigureConfig::mnist_compilers().steps_per_epoch > m.steps_per_epoch);
    }

    #[test]
    fn metric_names() {
        assert!(metric_name(&FigureConfig::mnist()).contains("12 epochs"));
        assert!(metric_name(&FigureConfig::resnet()).contains("per epoch"));
    }
}
