//! The MODAK deployment service: the concurrent front door to the whole
//! stack (ROADMAP: serve heavy traffic, not one blocking call at a time).
//!
//! Request pipeline, four layers deep:
//!
//! ```text
//!   submit_many(Vec<Optimisation>)          (this module: work queue)
//!        │  planner worker threads
//!        ▼
//!   plan_deployment()                       (optimiser: select profile)
//!        │  shared RegistryHandle
//!        ▼
//!   BuildPool::build_cached()               (builder: digest-keyed dedup)
//!        │  register_image + qsub
//!        ▼
//!   TorqueServer slot scheduler             (scheduler: backfill + slots)
//! ```
//!
//! `submit_many` returns immediately with one [`PlanHandle`] per request;
//! planning, container builds, and dispatch proceed on worker threads. The
//! legacy one-shot `modak optimise` path runs through the same service (a
//! batch of one), so both paths produce identical plans by construction.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::container::BuildStats;
use crate::dsl::Optimisation;
use crate::optimiser::{plan_deployment, DeploymentPlan};
use crate::perfmodel::PerfModel;
use crate::registry::RegistryHandle;
use crate::runtime::Manifest;
use crate::scheduler::{JobId, TorqueServer};
use crate::trainer::TrainConfig;
use crate::util::timer::Stopwatch;

/// Shape of the service's testbed + worker pools.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub cpu_nodes: usize,
    pub gpu_nodes: usize,
    /// Job slots per node (1 = the paper's exclusive allocation).
    pub slots_per_node: usize,
    /// Concurrent container builds (the build pool's worker cap).
    pub max_build_workers: usize,
    /// Planner worker threads draining the request queue.
    pub planner_workers: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            cpu_nodes: 3,
            gpu_nodes: 2,
            slots_per_node: 2,
            max_build_workers: 2,
            planner_workers: 4,
        }
    }
}

/// One request in a batch: a label (e.g. the DSL file name) + parsed DSL.
#[derive(Debug, Clone)]
pub struct BatchRequest {
    pub label: String,
    pub dsl: Optimisation,
}

/// What a planner worker produced for one request.
#[derive(Debug)]
pub struct PlanOutcome {
    pub plan: Result<DeploymentPlan>,
    /// Set when the plan was dispatched to the scheduler.
    pub job_id: Option<JobId>,
}

/// Async-style handle to one submitted request. `wait()` blocks until the
/// planner worker has planned (and, when dispatching, qsub'd) the request.
pub struct PlanHandle {
    pub index: usize,
    pub label: String,
    rx: Receiver<PlanOutcome>,
    outcome: Option<PlanOutcome>,
}

impl PlanHandle {
    /// Block until the request is planned; repeated calls are cheap.
    pub fn wait(&mut self) -> &PlanOutcome {
        if self.outcome.is_none() {
            let out = self.rx.recv().unwrap_or_else(|_| PlanOutcome {
                plan: Err(anyhow!("planner worker died before reporting")),
                job_id: None,
            });
            self.outcome = Some(out);
        }
        self.outcome.as_ref().expect("outcome just set")
    }
}

struct Work {
    req: BatchRequest,
    done: Sender<PlanOutcome>,
}

/// Per-job line of a [`BatchReport`].
#[derive(Debug, Clone)]
pub struct JobSummary {
    pub label: String,
    pub image: Option<String>,
    pub job_id: Option<JobId>,
    /// qstat code ('C'/'F'/...), 'P' = planned but not dispatched,
    /// 'E' = planning/build failed.
    pub state: char,
    pub queue_wait_secs: Option<f64>,
    pub run_secs: Option<f64>,
    pub node: Option<usize>,
    pub predicted_secs: Option<f64>,
    pub error: Option<String>,
}

/// Outcome of a whole batch: per-job lines + concurrency evidence.
#[derive(Debug, Clone)]
pub struct BatchReport {
    pub jobs: Vec<JobSummary>,
    /// Wall time from submission of the batch to the last terminal job.
    pub makespan_secs: f64,
    /// Sum of per-job run wall times (what serial FIFO would cost at best).
    pub serial_sum_secs: f64,
    /// Most jobs observed Running simultaneously.
    pub peak_running: usize,
    pub build_stats: BuildStats,
}

impl BatchReport {
    pub fn completed(&self) -> usize {
        self.jobs.iter().filter(|j| j.state == 'C').count()
    }

    pub fn throughput_jobs_per_sec(&self) -> f64 {
        if self.makespan_secs > 0.0 {
            self.completed() as f64 / self.makespan_secs
        } else {
            0.0
        }
    }

    /// Human-readable summary (the serve-batch CLI output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<22} {:<34} {:>4} {:>2} {:>9} {:>9} {:>5}\n",
            "request", "image", "job", "st", "wait(s)", "run(s)", "node"
        ));
        for j in &self.jobs {
            let fmt_opt = |v: Option<f64>| match v {
                Some(v) => format!("{v:.2}"),
                None => "-".into(),
            };
            out.push_str(&format!(
                "{:<22} {:<34} {:>4} {:>2} {:>9} {:>9} {:>5}\n",
                truncate(&j.label, 22),
                truncate(j.image.as_deref().unwrap_or("-"), 34),
                j.job_id.map(|i| i.to_string()).unwrap_or_else(|| "-".into()),
                j.state,
                fmt_opt(j.queue_wait_secs),
                fmt_opt(j.run_secs),
                j.node.map(|n| n.to_string()).unwrap_or_else(|| "-".into()),
            ));
            if let Some(e) = &j.error {
                out.push_str(&format!("{:<22}   error: {}\n", "", truncate(e, 100)));
            }
        }
        let speedup = if self.makespan_secs > 0.0 {
            self.serial_sum_secs / self.makespan_secs
        } else {
            0.0
        };
        out.push_str(&format!(
            "\nmakespan {:.2}s | serial sum {:.2}s ({speedup:.2}x) | \
             throughput {:.2} jobs/s\n",
            self.makespan_secs,
            self.serial_sum_secs,
            self.throughput_jobs_per_sec()
        ));
        out.push_str(&format!(
            "peak concurrent running {} | builds {} | build-cache hits {}\n",
            self.peak_running, self.build_stats.builds, self.build_stats.cache_hits
        ));
        out
    }
}

fn truncate(s: &str, n: usize) -> String {
    if s.chars().count() <= n {
        s.to_string()
    } else {
        let cut: String = s.chars().take(n.saturating_sub(1)).collect();
        format!("{cut}…")
    }
}

/// The deployment service: owns registry handle, performance model,
/// manifest, and the batch server, and drives requests through a work
/// queue of planner threads.
pub struct DeploymentService {
    registry: RegistryHandle,
    model: Arc<PerfModel>,
    manifest: Manifest,
    server: Arc<Mutex<TorqueServer>>,
    planner_workers: usize,
}

impl DeploymentService {
    /// Build a service over a fresh registry at `store`.
    pub fn new(
        store: impl AsRef<std::path::Path>,
        manifest: Manifest,
        model: PerfModel,
        cfg: &ServiceConfig,
    ) -> DeploymentService {
        let registry = RegistryHandle::open(store, &manifest, cfg.max_build_workers);
        Self::with_registry(registry, manifest, model, cfg)
    }

    /// Build a service over an existing (possibly shared) registry handle.
    pub fn with_registry(
        registry: RegistryHandle,
        manifest: Manifest,
        model: PerfModel,
        cfg: &ServiceConfig,
    ) -> DeploymentService {
        let server = TorqueServer::boot_slotted(cfg.cpu_nodes, cfg.gpu_nodes, cfg.slots_per_node);
        DeploymentService {
            registry,
            model: Arc::new(model),
            manifest,
            server: Arc::new(Mutex::new(server)),
            planner_workers: cfg.planner_workers.max(1),
        }
    }

    pub fn registry(&self) -> &RegistryHandle {
        &self.registry
    }

    /// Run `f` with the batch server locked (qstat snapshots, tests).
    pub fn with_server<R>(&self, f: impl FnOnce(&mut TorqueServer) -> R) -> R {
        f(&mut self.server.lock().unwrap())
    }

    /// Submit a batch of requests. Returns one handle per request, in
    /// input order, immediately; planner workers drain the queue in the
    /// background, building containers through the shared pool and (when
    /// `dispatch`) qsub'ing each plan as soon as it is ready.
    pub fn submit_many(
        &self,
        reqs: Vec<BatchRequest>,
        cfg: &TrainConfig,
        dispatch: bool,
    ) -> Vec<PlanHandle> {
        let (work_tx, work_rx) = channel::<Work>();
        let work_rx = Arc::new(Mutex::new(work_rx));
        let mut handles = Vec::with_capacity(reqs.len());
        for (index, req) in reqs.into_iter().enumerate() {
            let (done_tx, done_rx) = channel();
            handles.push(PlanHandle {
                index,
                label: req.label.clone(),
                rx: done_rx,
                outcome: None,
            });
            work_tx
                .send(Work { req, done: done_tx })
                .expect("work queue open");
        }
        drop(work_tx); // workers exit when the queue drains

        let workers = self.planner_workers.min(handles.len().max(1));
        for w in 0..workers {
            let work_rx = Arc::clone(&work_rx);
            let registry = self.registry.clone();
            let model = Arc::clone(&self.model);
            let manifest = self.manifest.clone();
            let server = Arc::clone(&self.server);
            let cfg = cfg.clone();
            std::thread::Builder::new()
                .name(format!("planner-{w}"))
                .spawn(move || loop {
                    // the lock is only held for the dequeue: all work was
                    // enqueued before the workers started, so recv never
                    // blocks other workers out
                    let work = work_rx.lock().unwrap().recv();
                    let Ok(Work { req, done }) = work else { break };
                    let outcome = plan_and_dispatch(
                        &registry, &model, &manifest, &server, &req, &cfg, dispatch,
                    );
                    let _ = done.send(outcome);
                })
                .expect("spawning planner worker");
        }
        handles
    }

    /// Wait for every handle's plan and every dispatched job to reach a
    /// terminal state, invoking `on_poll` with the locked server at each
    /// poll tick (for live qstat output). Returns the batch report with
    /// `makespan_secs` left at 0 (callers that timed the batch fill it in;
    /// [`Self::run_batch`] does this automatically).
    pub fn await_batch(
        &self,
        handles: &mut [PlanHandle],
        mut on_poll: impl FnMut(&TorqueServer),
    ) -> BatchReport {
        for h in handles.iter_mut() {
            h.wait();
        }
        let job_ids: Vec<JobId> = handles
            .iter()
            .filter_map(|h| h.outcome.as_ref().and_then(|o| o.job_id))
            .collect();
        loop {
            let pending = {
                let mut srv = self.server.lock().unwrap();
                let _ = srv.poll();
                on_poll(&srv);
                job_ids
                    .iter()
                    .filter(|id| {
                        srv.job(**id)
                            .map(|r| !r.state.is_terminal())
                            .unwrap_or(false)
                    })
                    .count()
            };
            if pending == 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(15));
        }
        self.report(handles, 0.0)
    }

    /// Submit + await + time a batch: the serve-batch entry point.
    pub fn run_batch(
        &self,
        reqs: Vec<BatchRequest>,
        cfg: &TrainConfig,
        on_poll: impl FnMut(&TorqueServer),
    ) -> BatchReport {
        let sw = Stopwatch::start();
        let mut handles = self.submit_many(reqs, cfg, true);
        let mut report = self.await_batch(&mut handles, on_poll);
        report.makespan_secs = sw.elapsed_secs();
        report
    }

    fn report(&self, handles: &mut [PlanHandle], makespan_secs: f64) -> BatchReport {
        let srv = self.server.lock().unwrap();
        let mut jobs = Vec::with_capacity(handles.len());
        let mut serial_sum = 0.0;
        for h in handles.iter_mut() {
            let label = h.label.clone();
            let out = h.wait();
            let summary = match &out.plan {
                Err(e) => JobSummary {
                    label,
                    image: None,
                    job_id: None,
                    state: 'E',
                    queue_wait_secs: None,
                    run_secs: None,
                    node: None,
                    predicted_secs: None,
                    error: Some(format!("{e:#}")),
                },
                Ok(plan) => {
                    let image = Some(plan.profile.image_tag());
                    match out.job_id.and_then(|id| srv.job(id).ok()) {
                        None => JobSummary {
                            label,
                            image,
                            job_id: None,
                            state: 'P',
                            queue_wait_secs: None,
                            run_secs: None,
                            node: None,
                            predicted_secs: plan.predicted_secs,
                            error: None,
                        },
                        Some(rec) => {
                            let run_secs = rec.state.wall_secs();
                            if let Some(s) = run_secs {
                                serial_sum += s;
                            }
                            let error = match &rec.state {
                                crate::scheduler::JobState::Failed { error, .. } => {
                                    Some(error.clone())
                                }
                                _ => None,
                            };
                            JobSummary {
                                label,
                                image,
                                job_id: Some(rec.id),
                                state: rec.state.code(),
                                queue_wait_secs: rec.queue_wait_secs,
                                run_secs,
                                node: rec.node,
                                predicted_secs: plan.predicted_secs,
                                error,
                            }
                        }
                    }
                }
            };
            jobs.push(summary);
        }
        BatchReport {
            jobs,
            makespan_secs,
            serial_sum_secs: serial_sum,
            peak_running: srv.peak_running(),
            build_stats: self.registry.build_stats(),
        }
    }
}

fn plan_and_dispatch(
    registry: &RegistryHandle,
    model: &PerfModel,
    manifest: &Manifest,
    server: &Arc<Mutex<TorqueServer>>,
    req: &BatchRequest,
    cfg: &TrainConfig,
    dispatch: bool,
) -> PlanOutcome {
    let plan = match plan_deployment(registry, model, manifest, &req.dsl, cfg) {
        Ok(p) => p,
        Err(e) => {
            return PlanOutcome {
                plan: Err(e),
                job_id: None,
            }
        }
    };
    let job_id = if dispatch {
        let mut srv = server.lock().unwrap();
        srv.register_image(&plan.profile.image_tag(), plan.image.dir.clone());
        match srv.qsub(plan.script.clone()) {
            Ok(id) => Some(id),
            Err(e) => {
                return PlanOutcome {
                    plan: Err(e.context(format!("dispatching plan for {}", req.label))),
                    job_id: None,
                }
            }
        }
    } else {
        None
    };
    PlanOutcome {
        plan: Ok(plan),
        job_id,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn store(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("modak_service_tests").join(name);
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// A manifest with no workloads: planning succeeds up to the build,
    /// then fails deterministically — enough to exercise the queue
    /// plumbing and the digest-keyed failure cache without artifacts.
    fn empty_manifest() -> Manifest {
        Manifest {
            dir: PathBuf::from("artifacts-not-needed"),
            workloads: Default::default(),
            artifacts: Default::default(),
        }
    }

    fn dsl(framework: &str, version: &str) -> Optimisation {
        Optimisation::parse(&format!(
            r#"{{"app_type": "ai_training",
                "ai_training": {{"{framework}": {{"version": "{version}"}}}}}}"#
        ))
        .unwrap()
    }

    #[test]
    fn submit_many_preserves_order_and_reports_errors() {
        let service = DeploymentService::new(
            store("order"),
            empty_manifest(),
            PerfModel::new(),
            &ServiceConfig::default(),
        );
        let reqs = vec![
            BatchRequest { label: "a".into(), dsl: dsl("pytorch", "1.14") },
            BatchRequest { label: "b".into(), dsl: dsl("tensorflow", "2.1") },
            BatchRequest { label: "c".into(), dsl: dsl("pytorch", "1.14") },
        ];
        let cfg = TrainConfig { epochs: 1, steps_per_epoch: 1, seed: 0 };
        let mut handles = service.submit_many(reqs, &cfg, true);
        assert_eq!(handles.len(), 3);
        for (i, h) in handles.iter_mut().enumerate() {
            assert_eq!(h.index, i);
            let label = h.label.clone();
            // without artifacts every build fails; the outcome must be a
            // clean error, never a hang or a dispatched job
            let out = h.wait();
            assert!(out.plan.is_err(), "{label}: {:?}", out.plan);
            assert!(out.job_id.is_none());
        }
        assert_eq!(handles[0].label, "a");
        assert_eq!(handles[2].label, "c");
        // identical requests a and c share one (failed) build slot:
        // the digest-keyed cache deduplicated the second attempt
        let stats = service.registry().build_stats();
        assert_eq!(stats.builds, 0);
        assert!(stats.cache_hits >= 1, "{stats:?}");
    }

    #[test]
    fn await_batch_returns_report_for_undispatched_batch() {
        let service = DeploymentService::new(
            store("report"),
            empty_manifest(),
            PerfModel::new(),
            &ServiceConfig { planner_workers: 2, ..ServiceConfig::default() },
        );
        let cfg = TrainConfig { epochs: 1, steps_per_epoch: 1, seed: 0 };
        let mut handles = service.submit_many(
            vec![BatchRequest { label: "only".into(), dsl: dsl("mxnet", "2.0") }],
            &cfg,
            false,
        );
        let mut polls = 0;
        let report = service.await_batch(&mut handles, |_srv| polls += 1);
        assert_eq!(report.jobs.len(), 1);
        assert_eq!(report.jobs[0].state, 'E'); // build failed without artifacts
        assert!(report.jobs[0].error.is_some());
        assert!(polls >= 1);
        assert_eq!(report.completed(), 0);
        // render() must not panic on degenerate reports
        assert!(report.render().contains("makespan"));
    }
}
