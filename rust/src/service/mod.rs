//! The MODAK deployment service: the concurrent front door to the whole
//! stack (ROADMAP: serve heavy traffic, not one blocking call at a time).
//!
//! Request pipeline, four layers deep:
//!
//! ```text
//!   submit_many(Vec<Optimisation>)          (this module: work queue)
//!        │  planner worker threads
//!        ▼
//!   plan_deployment()                       (optimiser: select profile)
//!        │  shared RegistryHandle
//!        ▼
//!   BuildPool::build_cached()               (builder: digest-keyed dedup)
//!        │  register_image + qsub
//!        ▼
//!   TorqueServer slot scheduler             (scheduler: backfill + slots)
//! ```
//!
//! `submit_many` returns immediately with one [`PlanHandle`] per request;
//! planning, container builds, and dispatch proceed on worker threads. The
//! legacy one-shot `modak optimise` path runs through the same service (a
//! batch of one), so both paths produce identical plans by construction.
//!
//! The scheduling substrate is a [`ClusterScheduler`]: one shard by
//! default (the embedded single-server shape, unchanged semantics), or —
//! with `shards > 1` — a heterogeneous multi-shard cluster where every
//! dispatch is routed by the pluggable [`ShardRouter`], bundles are staged
//! into shard-local stores by the image distributor, and still-queued work
//! is rebalanced off backlogged shards. Batch completion is event-driven
//! end to end: scheduler events (submit/dispatch/complete/preempt/
//! checkpoint-ready) flow over the cluster's typed
//! [`EventBus`](crate::util::sync::EventBus), every publish pings the
//! shared condvar ([`Signal`]), and `await_batch` drains the bus on each
//! wake to poll only the shards the events name — the full-cluster sweep
//! survives only as a timeout/overflow backstop.
//!
//! The performance model is closed-loop: predictions ride into the
//! scheduler on each job script (driving `sjf` packing and `reservation`
//! shadow windows), and every completed job's measured wall time is fed
//! back through [`PerfModel::observe`] — an online refit persisted via
//! `save()`, so the next batch plans on fresher coefficients.

use std::collections::HashSet;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::cluster::{
    ClusterConfig, ClusterJobId, ClusterScheduler, ShardRouter, ShardSpec, StagingStats,
};
use crate::placement::RebalanceMode;
use crate::container::BuildStats;
use crate::data::stage::DataStageStats;
use crate::data::DatasetCatalog;
use crate::dsl::Optimisation;
use crate::obs::collect::Recorder;
use crate::obs::http::PlaneState;
use crate::obs::slo::SloWatchdog;
use crate::obs::window::WindowSet;
use crate::optimiser::{plan_deployment, DeploymentPlan};
use crate::perfmodel::{Features, PerfModel, Record};
use crate::registry::RegistryHandle;
use crate::runtime::Manifest;
use crate::scheduler::{JobState, SchedulePolicy, TorqueServer};
use crate::trainer::TrainConfig;
use crate::util::json::Json;
use crate::util::sync::{lock_or_recover, read_or_recover, write_or_recover, Signal};
use crate::util::timer::Stopwatch;

/// Shape of the service's testbed + worker pools.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub cpu_nodes: usize,
    pub gpu_nodes: usize,
    /// Job slots per node (1 = the paper's exclusive allocation).
    pub slots_per_node: usize,
    /// Concurrent container builds (the build pool's worker cap).
    pub max_build_workers: usize,
    /// Planner worker threads draining the request queue.
    pub planner_workers: usize,
    /// Dispatch rule for every batch-server shard (`--policy`).
    pub policy: SchedulePolicy,
    /// Scheduler shards (`--shards`). 1 = the embedded single server;
    /// more boots a heterogeneous cluster varied around the node counts
    /// above (see [`ShardSpec::heterogeneous`]).
    pub shards: usize,
    /// Shard routing rule (`--router`), used when `shards > 1`.
    pub router: ShardRouter,
    /// Byte cap (in MB) on the bundle store and the per-shard caches
    /// (`--store-cap-mb`): cold image bundles and datasets past the cap
    /// are garbage-collected LRU-first — digests still referenced by
    /// queued/running jobs are reference-pinned and never evicted.
    /// None = unbounded.
    pub store_cap_mb: Option<u64>,
    /// What the cluster rebalancer may migrate (`--rebalance`): queued
    /// jobs only, or also running jobs via checkpoint/restart.
    pub rebalance: RebalanceMode,
    /// Per-shard dispatch-policy overrides (`--policy-shard N=<policy>`,
    /// repeatable); unlisted shards run `policy`. Out-of-range indices
    /// are ignored.
    pub shard_policies: Vec<(usize, SchedulePolicy)>,
    /// Migration hysteresis (`--rebalance-margin-secs`): a migration must
    /// improve the destination's placement score by at least this many
    /// seconds over the origin's. 0.0 keeps the historical strict
    /// "any improvement" rule; larger margins damp ping-pong migrations
    /// under near-symmetric load.
    pub rebalance_margin_secs: f64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            cpu_nodes: 3,
            gpu_nodes: 2,
            slots_per_node: 2,
            max_build_workers: 2,
            planner_workers: 4,
            policy: SchedulePolicy::Fifo,
            shards: 1,
            router: ShardRouter::RoundRobin,
            store_cap_mb: None,
            rebalance: RebalanceMode::Queued,
            shard_policies: Vec::new(),
            rebalance_margin_secs: 0.0,
        }
    }
}

impl ServiceConfig {
    fn cache_cap_bytes(&self) -> Option<u64> {
        self.store_cap_mb.map(|mb| mb * 1024 * 1024)
    }
}

/// One request in a batch: a label (e.g. the DSL file name) + parsed DSL.
#[derive(Debug, Clone)]
pub struct BatchRequest {
    pub label: String,
    pub dsl: Optimisation,
}

/// What a planner worker produced for one request.
#[derive(Debug)]
pub struct PlanOutcome {
    pub plan: Result<DeploymentPlan>,
    /// Set when the plan was dispatched to the scheduler (a cluster-global
    /// id, stable across shard migrations).
    pub job_id: Option<ClusterJobId>,
}

/// Async-style handle to one submitted request. `wait()` blocks until the
/// planner worker has planned (and, when dispatching, qsub'd) the request.
pub struct PlanHandle {
    pub index: usize,
    pub label: String,
    rx: Receiver<PlanOutcome>,
    outcome: Option<PlanOutcome>,
}

impl PlanHandle {
    /// Block until the request is planned; repeated calls are cheap.
    pub fn wait(&mut self) -> &PlanOutcome {
        if self.outcome.is_none() {
            let out = self.rx.recv().unwrap_or_else(|_| PlanOutcome {
                plan: Err(anyhow!("planner worker died before reporting")),
                job_id: None,
            });
            self.outcome = Some(out);
        }
        self.outcome.as_ref().expect("outcome just set")
    }

    /// Non-blocking probe: the outcome if the planner has reported yet.
    pub fn try_wait(&mut self) -> Option<&PlanOutcome> {
        if self.outcome.is_none() {
            match self.rx.try_recv() {
                Ok(out) => self.outcome = Some(out),
                Err(std::sync::mpsc::TryRecvError::Empty) => return None,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    self.outcome = Some(PlanOutcome {
                        plan: Err(anyhow!("planner worker died before reporting")),
                        job_id: None,
                    });
                }
            }
        }
        self.outcome.as_ref()
    }
}

struct Work {
    req: BatchRequest,
    done: Sender<PlanOutcome>,
}

/// Per-job line of a [`BatchReport`].
#[derive(Debug, Clone)]
pub struct JobSummary {
    pub label: String,
    pub image: Option<String>,
    pub job_id: Option<ClusterJobId>,
    /// qstat code ('C'/'F'/...), 'P' = planned but not dispatched,
    /// 'E' = planning/build failed.
    pub state: char,
    pub queue_wait_secs: Option<f64>,
    pub run_secs: Option<f64>,
    /// Shard the job (last) ran on.
    pub shard: Option<usize>,
    /// Node within that shard.
    pub node: Option<usize>,
    pub predicted_secs: Option<f64>,
    /// Queue-wait prediction from the model's separate wait target.
    pub predicted_wait_secs: Option<f64>,
    /// Simulated dataset-IO seconds the run's prefetcher paid (completed
    /// runs of jobs with a `dataset:` block only).
    pub io_secs: Option<f64>,
    /// Slice of `io_secs` the step loop actually stalled on.
    pub io_stall_secs: Option<f64>,
    pub error: Option<String>,
}

impl JobSummary {
    /// Signed predicted-vs-measured error in percent, for completed jobs
    /// with a prediction (positive = the model under-predicted).
    pub fn pct_error(&self) -> Option<f64> {
        match (self.state, self.predicted_secs, self.run_secs) {
            ('C', Some(pred), Some(run)) if pred > 0.0 => Some((run - pred) / pred * 100.0),
            _ => None,
        }
    }

    /// Signed wait-prediction error in percent — the model's *separate*
    /// queue-wait target, scored against the measured wait.
    pub fn wait_pct_error(&self) -> Option<f64> {
        match (self.state, self.predicted_wait_secs, self.queue_wait_secs) {
            ('C', Some(pred), Some(wait)) if pred > 0.0 => {
                Some((wait - pred) / pred * 100.0)
            }
            _ => None,
        }
    }
}

/// One shard's slice of a batch (tentpole: shard-aware reporting).
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub shard: usize,
    /// Jobs of this batch that finished on this shard.
    pub jobs: usize,
    pub completed: usize,
    /// Longest submission-to-finish span among this shard's jobs.
    pub makespan_secs: f64,
    /// Sum of completed run wall times on this shard.
    pub busy_secs: f64,
    /// busy / (makespan x slot capacity): how much of the shard's
    /// capacity the batch actually used while it had work.
    pub utilisation: f64,
    pub peak_running: usize,
    /// Jobs the rebalancer migrated onto this shard.
    pub migrations_in: u64,
    pub staging: StagingStats,
    /// Dataset staging counters for this shard (both tiers).
    pub data: DataStageStats,
    /// Mean IO-overlap ratio across this shard's completed data jobs
    /// (None when no job here simulated dataset IO): 1.0 = the prefetcher
    /// hid every IO second behind compute.
    pub io_overlap: Option<f64>,
}

/// Cluster-level slice of a [`BatchReport`].
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub router: String,
    /// Rebalance mode the cluster ran under (`queued` | `elastic`).
    pub rebalance: String,
    pub shards: Vec<ShardReport>,
    /// Total cross-shard migrations the rebalancer executed.
    pub migrations: u64,
    /// Slice of `migrations` done via checkpoint/restart of RUNNING jobs.
    pub elastic_migrations: u64,
    pub staging_totals: StagingStats,
    /// Cluster-wide dataset staging counters.
    pub data_totals: DataStageStats,
}

/// Outcome of a whole batch: per-job lines + concurrency evidence.
#[derive(Debug, Clone)]
pub struct BatchReport {
    pub jobs: Vec<JobSummary>,
    /// Wall time from submission of the batch to the last terminal job.
    pub makespan_secs: f64,
    /// Sum of *completed* jobs' run wall times (what serial FIFO would
    /// cost at best for the work that actually finished). Failed jobs are
    /// excluded on both sides of the speedup ratio.
    pub serial_sum_secs: f64,
    /// Most jobs observed Running simultaneously (summed across shards:
    /// exact for one shard, an upper bound for many).
    pub peak_running: usize,
    pub build_stats: BuildStats,
    /// Performance-model r² after feedback (None while untrained).
    pub model_r2: Option<f64>,
    /// Per-shard breakdown (always present when the batch ran through the
    /// service; rendered when the cluster has more than one shard).
    pub cluster: Option<ClusterReport>,
    /// Routing-decision latency quantiles (ledger read + route pick per
    /// submit), from the process-wide `route_decision_seconds` histogram;
    /// None before any cluster routing ran.
    pub route_p50_secs: Option<f64>,
    pub route_p99_secs: Option<f64>,
}

impl BatchReport {
    /// Assemble a report from per-job summaries; `serial_sum_secs` counts
    /// completed jobs only, so `completed()` / `throughput_jobs_per_sec`
    /// and the serial-vs-makespan speedup agree on what "the work" was.
    pub fn from_jobs(
        jobs: Vec<JobSummary>,
        makespan_secs: f64,
        peak_running: usize,
        build_stats: BuildStats,
        model_r2: Option<f64>,
    ) -> BatchReport {
        let serial_sum_secs = jobs
            .iter()
            .filter(|j| j.state == 'C')
            .filter_map(|j| j.run_secs)
            .sum();
        let route = &crate::obs::metrics::global().route_decision_seconds;
        let (route_p50_secs, route_p99_secs) = if route.count() > 0 {
            (Some(route.quantile(0.50)), Some(route.quantile(0.99)))
        } else {
            (None, None)
        };
        BatchReport {
            jobs,
            makespan_secs,
            serial_sum_secs,
            peak_running,
            build_stats,
            model_r2,
            cluster: None,
            route_p50_secs,
            route_p99_secs,
        }
    }

    pub fn completed(&self) -> usize {
        self.jobs.iter().filter(|j| j.state == 'C').count()
    }

    pub fn throughput_jobs_per_sec(&self) -> f64 {
        if self.makespan_secs > 0.0 {
            self.completed() as f64 / self.makespan_secs
        } else {
            0.0
        }
    }

    /// Mean |predicted-vs-measured| error in percent over completed jobs
    /// that carried a prediction.
    pub fn mean_abs_pct_error(&self) -> Option<f64> {
        self.mean_abs(JobSummary::pct_error)
    }

    /// Mean |predicted-vs-measured| QUEUE-WAIT error in percent — the
    /// model's separate wait target gets its own error column.
    pub fn mean_abs_wait_pct_error(&self) -> Option<f64> {
        self.mean_abs(JobSummary::wait_pct_error)
    }

    /// Mean of |selector| over the batch's jobs, None when no job yields a
    /// value (the one aggregation behind both error columns).
    fn mean_abs(&self, selector: impl Fn(&JobSummary) -> Option<f64>) -> Option<f64> {
        let errs: Vec<f64> = self.jobs.iter().filter_map(selector).collect();
        if errs.is_empty() {
            None
        } else {
            Some(errs.iter().map(|e| e.abs()).sum::<f64>() / errs.len() as f64)
        }
    }

    /// Human-readable summary (the serve-batch CLI output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<22} {:<30} {:>4} {:>2} {:>8} {:>8} {:>8} {:>7} {:>8} {:>7} {:>8}\n",
            "request",
            "image",
            "job",
            "st",
            "wait(s)",
            "run(s)",
            "pred(s)",
            "err%",
            "wpred(s)",
            "werr%",
            "sh/node"
        ));
        for j in &self.jobs {
            let fmt_opt = |v: Option<f64>| match v {
                Some(v) => format!("{v:.2}"),
                None => "-".into(),
            };
            let fmt_err = |v: Option<f64>| match v {
                Some(e) => format!("{e:+.1}"),
                None => "-".to_string(),
            };
            let place = match (j.shard, j.node) {
                (Some(s), Some(n)) => format!("s{s}/n{n}"),
                (None, Some(n)) => format!("n{n}"),
                _ => "-".into(),
            };
            out.push_str(&format!(
                "{:<22} {:<30} {:>4} {:>2} {:>8} {:>8} {:>8} {:>7} {:>8} {:>7} {:>8}\n",
                truncate(&j.label, 22),
                truncate(j.image.as_deref().unwrap_or("-"), 30),
                j.job_id.map(|i| i.to_string()).unwrap_or_else(|| "-".into()),
                j.state,
                fmt_opt(j.queue_wait_secs),
                fmt_opt(j.run_secs),
                fmt_opt(j.predicted_secs),
                fmt_err(j.pct_error()),
                fmt_opt(j.predicted_wait_secs),
                fmt_err(j.wait_pct_error()),
                place,
            ));
            if let Some(e) = &j.error {
                out.push_str(&format!("{:<22}   error: {}\n", "", truncate(e, 100)));
            }
        }
        let speedup = if self.makespan_secs > 0.0 {
            self.serial_sum_secs / self.makespan_secs
        } else {
            0.0
        };
        out.push_str(&format!(
            "\nmakespan {:.2}s | serial sum {:.2}s ({speedup:.2}x) | \
             throughput {:.2} jobs/s\n",
            self.makespan_secs,
            self.serial_sum_secs,
            self.throughput_jobs_per_sec()
        ));
        out.push_str(&format!(
            "peak concurrent running {} | builds {} | build-cache hits {}\n",
            self.peak_running, self.build_stats.builds, self.build_stats.cache_hits
        ));
        match (self.mean_abs_pct_error(), self.model_r2) {
            (Some(err), Some(r2)) => out.push_str(&format!(
                "prediction mean abs err {err:.1}% | model r2 {r2:.3} (after feedback)\n"
            )),
            (None, Some(r2)) => {
                out.push_str(&format!("model r2 {r2:.3} (after feedback)\n"))
            }
            _ => {}
        }
        // the queue-wait target is fit separately: its error column gets
        // its own summary line
        if let Some(werr) = self.mean_abs_wait_pct_error() {
            out.push_str(&format!(
                "queue-wait mean abs err {werr:.1}% (separate wait target)\n"
            ));
        }
        if let (Some(p50), Some(p99)) = (self.route_p50_secs, self.route_p99_secs) {
            out.push_str(&format!(
                "routing decision p50 {:.1}us | p99 {:.1}us (incremental ledger)\n",
                p50 * 1e6,
                p99 * 1e6
            ));
        }
        // dataset staging summary whenever the batch actually moved data
        if let Some(c) = self.cluster.as_ref() {
            let d = &c.data_totals;
            if d.misses() + d.hits() > 0 {
                out.push_str(&format!(
                    "data staging: {} miss / {} hit | {:.1} MB moved \
                     ({:.2}s simulated) | {} evicted\n",
                    d.misses(),
                    d.hits(),
                    d.bytes_moved as f64 / (1024.0 * 1024.0),
                    d.simulated_secs,
                    d.evictions,
                ));
            }
        }
        // per-shard section only when there is more than one shard to show
        if let Some(c) = self.cluster.as_ref().filter(|c| c.shards.len() > 1) {
            out.push_str(&format!(
                "cluster: {} shards | router {} | rebalance {} | \
                 migrations {} ({} elastic) | \
                 staging {} miss / {} hit ({:.2}s simulated transfer)\n",
                c.shards.len(),
                c.router,
                c.rebalance,
                c.migrations,
                c.elastic_migrations,
                c.staging_totals.misses,
                c.staging_totals.hits,
                c.staging_totals.simulated_secs,
            ));
            for s in &c.shards {
                let io = match s.io_overlap {
                    Some(r) => format!(" | io-overlap {:.0}%", r * 100.0),
                    None => String::new(),
                };
                out.push_str(&format!(
                    "  shard {}: {} jobs ({} C) | makespan {:>7.2}s | \
                     util {:>3.0}% | peak {} | staged {}m/{}h | \
                     data {}m/{}h | +{} migrated in{io}\n",
                    s.shard,
                    s.jobs,
                    s.completed,
                    s.makespan_secs,
                    s.utilisation * 100.0,
                    s.peak_running,
                    s.staging.misses,
                    s.staging.hits,
                    s.data.misses(),
                    s.data.hits(),
                    s.migrations_in,
                ));
            }
        }
        out
    }
}

fn truncate(s: &str, n: usize) -> String {
    if s.chars().count() <= n {
        s.to_string()
    } else {
        let cut: String = s.chars().take(n.saturating_sub(1)).collect();
        format!("{cut}…")
    }
}

/// The live observability plane's mutable half: rolling windows over
/// the registry plus the SLO watchdog that reads them. Both sit behind
/// ONE `Obs`-ranked lock — sampling and ticking are a single
/// acquisition, so the plane can never stack two same-rank guards
/// (strict-ascent discipline), and fired alerts are published only
/// after the guard drops.
struct LivePlane {
    windows: WindowSet,
    watchdog: SloWatchdog,
}

/// The deployment service: owns registry handle, performance model,
/// manifest, and the scheduler cluster, and drives requests through a
/// work queue of planner threads.
pub struct DeploymentService {
    registry: RegistryHandle,
    /// Shared mutable model: planners snapshot it per request; completed
    /// jobs feed measured wall times back into it (online refit). An
    /// RwLock so concurrent planner snapshots never serialise on each
    /// other — only the refit takes the write side.
    model: Arc<RwLock<PerfModel>>,
    manifest: Manifest,
    /// Dataset catalog `dataset:` blocks resolve against (immutable:
    /// ad-hoc DSL declarations carry their own shape).
    catalog: Arc<DatasetCatalog>,
    /// The scheduling substrate: one shard = the embedded single server,
    /// more = the routed multi-shard cluster.
    cluster: Arc<ClusterScheduler>,
    /// Completion signal: pinged by every node result (via the cluster's
    /// shards) and every planner report; `await_batch` sleeps on it.
    signal: Arc<Signal>,
    planner_workers: usize,
    /// Flight recorder: taps the cluster's event bus (non-consuming, own
    /// cursor) for lifecycle spans and takes explicit `plan`/`build` span
    /// reports from the planner workers. Shared so workers can record
    /// while `await_batch` drains.
    recorder: Arc<Recorder>,
    /// Jobs whose measured results were already fed back to the model.
    fed_back: Mutex<HashSet<ClusterJobId>>,
    /// Jobs whose store-GC image pin was already released (terminal).
    unpinned: Mutex<HashSet<ClusterJobId>>,
    /// The live observability plane (rolling windows + SLO watchdog),
    /// sampled once per `await_batch` sweep. Innermost rank (`Obs`),
    /// like the recorder — taken only with every scheduler lock
    /// released.
    plane: Mutex<LivePlane>,
}

impl DeploymentService {
    /// Build a service over a fresh registry at `store`.
    pub fn new(
        store: impl AsRef<std::path::Path>,
        manifest: Manifest,
        model: PerfModel,
        cfg: &ServiceConfig,
    ) -> DeploymentService {
        let registry = RegistryHandle::open_capped(
            store,
            &manifest,
            cfg.max_build_workers,
            cfg.cache_cap_bytes(),
        );
        Self::with_registry(registry, manifest, model, cfg)
    }

    /// Build a service over an existing (possibly shared) registry handle.
    pub fn with_registry(
        registry: RegistryHandle,
        manifest: Manifest,
        model: PerfModel,
        cfg: &ServiceConfig,
    ) -> DeploymentService {
        let signal = Arc::new(Signal::new());
        let base = ShardSpec {
            cpu_nodes: cfg.cpu_nodes,
            gpu_nodes: cfg.gpu_nodes,
            slots_per_node: cfg.slots_per_node,
            policy: None,
        };
        let mut shard_specs = ShardSpec::heterogeneous(cfg.shards.max(1), &base);
        for (i, policy) in &cfg.shard_policies {
            if let Some(spec) = shard_specs.get_mut(*i) {
                spec.policy = Some(*policy);
            }
        }
        let cluster_cfg = ClusterConfig {
            shards: shard_specs,
            router: cfg.router,
            policy: cfg.policy,
            cache_cap_bytes: cfg.cache_cap_bytes(),
            rebalance: cfg.rebalance,
            rebalance_margin_secs: cfg.rebalance_margin_secs,
        };
        let store_root = registry.with(|r| r.store().to_path_buf());
        let cluster = Arc::new(ClusterScheduler::new(
            store_root,
            &cluster_cfg,
            Arc::clone(&signal),
        ));
        DeploymentService {
            registry,
            model: Arc::new(RwLock::new(model)),
            manifest,
            catalog: Arc::new(DatasetCatalog::builtin()),
            cluster,
            signal,
            planner_workers: cfg.planner_workers.max(1),
            recorder: Arc::new(Recorder::new()),
            fed_back: Mutex::new(HashSet::new()),
            unpinned: Mutex::new(HashSet::new()),
            plane: Mutex::new(LivePlane {
                windows: WindowSet::default_plane(),
                watchdog: SloWatchdog::default_plane(),
            }),
        }
    }

    pub fn registry(&self) -> &RegistryHandle {
        &self.registry
    }

    /// The scheduler cluster behind this service.
    pub fn cluster(&self) -> &Arc<ClusterScheduler> {
        &self.cluster
    }

    /// The batch's flight recorder (span trees + bus-tap lifecycle
    /// events). Drained by `await_batch`; exporters read it via
    /// [`Recorder::finish`] after the batch settles.
    pub fn recorder(&self) -> &Arc<Recorder> {
        &self.recorder
    }

    /// Run `f` with shard 0's batch server locked (qstat snapshots,
    /// tests; with the default single shard this IS the batch server).
    pub fn with_server<R>(&self, f: impl FnOnce(&mut TorqueServer) -> R) -> R {
        self.cluster.with_shard(0, f)
    }

    /// Run `f` on a dispatched job's record, wherever it lives.
    pub fn with_job<R>(
        &self,
        id: ClusterJobId,
        f: impl FnOnce(&crate::scheduler::JobRecord) -> R,
    ) -> Result<R> {
        self.cluster.with_job(id, f)
    }

    /// Run `f` with the performance model read-locked (feedback
    /// inspection, persisting, tests).
    pub fn with_model<R>(&self, f: impl FnOnce(&PerfModel) -> R) -> R {
        f(&read_or_recover(&self.model))
    }

    /// Submit a batch of requests. Returns one handle per request, in
    /// input order, immediately; planner workers drain the queue in the
    /// background, building containers through the shared pool and (when
    /// `dispatch`) qsub'ing each plan as soon as it is ready.
    pub fn submit_many(
        &self,
        reqs: Vec<BatchRequest>,
        cfg: &TrainConfig,
        dispatch: bool,
    ) -> Vec<PlanHandle> {
        let (work_tx, work_rx) = channel::<Work>();
        let work_rx = Arc::new(Mutex::new(work_rx));
        let mut handles = Vec::with_capacity(reqs.len());
        for (index, req) in reqs.into_iter().enumerate() {
            let (done_tx, done_rx) = channel();
            handles.push(PlanHandle {
                index,
                label: req.label.clone(),
                rx: done_rx,
                outcome: None,
            });
            work_tx
                .send(Work { req, done: done_tx })
                .expect("work queue open");
        }
        drop(work_tx); // workers exit when the queue drains

        let workers = self.planner_workers.min(handles.len().max(1));
        for w in 0..workers {
            let work_rx = Arc::clone(&work_rx);
            let registry = self.registry.clone();
            let model = Arc::clone(&self.model);
            let manifest = self.manifest.clone();
            let catalog = Arc::clone(&self.catalog);
            let cluster = Arc::clone(&self.cluster);
            let signal = Arc::clone(&self.signal);
            let recorder = Arc::clone(&self.recorder);
            let cfg = cfg.clone();
            std::thread::Builder::new()
                .name(format!("planner-{w}"))
                .spawn(move || loop {
                    // the lock is only held for the dequeue: all work was
                    // enqueued before the workers started, so recv never
                    // blocks other workers out
                    let work = lock_or_recover(&work_rx).recv();
                    let Ok(Work { req, done }) = work else { break };
                    let outcome = plan_and_dispatch(
                        &registry, &model, &manifest, &catalog, &cluster, &recorder, &req,
                        &cfg, dispatch,
                    );
                    let _ = done.send(outcome);
                    // wake await_batch: a handle just became resolvable
                    signal.notify();
                })
                .expect("spawning planner worker");
        }
        handles
    }

    /// Wait for every handle's plan and every dispatched job to reach a
    /// terminal state, invoking `on_poll` with the cluster at each sweep
    /// (for live qstat output). Returns the batch report with
    /// `makespan_secs` left at 0 (callers that timed the batch fill it in;
    /// [`Self::run_batch`] does this automatically).
    ///
    /// Completion latency is event-driven, not poll-quantised: every
    /// scheduler event (submit/dispatch/complete/preempt/checkpoint-ready)
    /// lands on the cluster's typed [`EventBus`](crate::util::sync::EventBus)
    /// whose publishes ping the shared [`Signal`], and this loop sleeps on
    /// it between sweeps. Each wake drains the bus and polls **only the
    /// shards named in the drained events**; a full-cluster sweep runs
    /// only when the drain comes back empty (the periodic rebalance tick)
    /// or the consumer fell behind the bus ring (`missed > 0`). The epoch
    /// is read *before* each sweep, so an event landing mid-sweep makes
    /// the wait return immediately — no lost wakeups. The wait's timeout
    /// is only a rebalancing tick + robustness backstop.
    pub fn await_batch(
        &self,
        handles: &mut [PlanHandle],
        mut on_poll: impl FnMut(&ClusterScheduler),
    ) -> BatchReport {
        let bus = self.cluster.bus();
        // cursor 0: the first drain replays every event since boot, so
        // submits that landed before this call still get a targeted pass
        // (or overflow into the full-sweep backstop)
        let mut cursor = 0u64;
        loop {
            let seen = self.signal.epoch();
            let mut all_planned = true;
            for h in handles.iter_mut() {
                if h.try_wait().is_none() {
                    all_planned = false;
                }
            }
            // live feedback: measured wall times land in the model as each
            // job completes, so planner workers still working through this
            // batch's queue (and every later request) snapshot refreshed
            // coefficients
            self.feed_back_measurements(handles);
            // terminal jobs release their store-GC image pins: their
            // bundles become ordinary LRU prey again
            self.release_finished_image_pins(handles);
            // absorb completions: a targeted pass over the shards named in
            // drained events, falling back to the full sweep when there is
            // nothing to aim at (timeout tick) or events were lost to the
            // ring cap
            let drained = bus.drain_since(cursor);
            cursor = drained.seen;
            // the flight recorder tails the same bus on its own cursor:
            // a second consumer, so this sweep's targeted drain above is
            // unaffected (exactly-once is per cursor, not per bus)
            self.recorder.drain(&bus);
            // the sweep is timed: bookkeeping seconds per drained event
            // feed the lifetime scheduler-overhead histogram, whose
            // rolling window the SLO watchdog's overhead budget reads
            let sweep = Stopwatch::start();
            if drained.missed > 0 || drained.events.is_empty() {
                let _ = self.cluster.poll();
            } else {
                let mut shards: Vec<usize> =
                    drained.events.iter().map(|e| e.shard()).collect();
                shards.sort_unstable();
                shards.dedup();
                let _ = self.cluster.poll_shards(&shards);
            }
            crate::obs::metrics::global()
                .scheduler_overhead_seconds
                .observe(sweep.elapsed_secs() / drained.events.len().max(1) as f64);
            on_poll(&self.cluster);
            let pending_jobs = handles
                .iter()
                .filter_map(|h| h.outcome.as_ref().and_then(|o| o.job_id))
                .filter(|id| !self.cluster.job_terminal(*id).unwrap_or(true))
                .count();
            crate::obs::metrics::global()
                .queue_depth
                .set(pending_jobs as f64);
            // live plane sweep: fold fresh registry/staging deltas into
            // the rolling windows, tick the SLO watchdog, publish
            // whatever fired (collect-then-publish; see observe_plane)
            self.observe_plane();
            if all_planned && pending_jobs == 0 {
                break;
            }
            self.signal.wait_past(seen, Duration::from_millis(200));
        }
        // final sweep: completions absorbed by the last poll above; the
        // recorder absorbs any events published between the loop's last
        // drain and the final terminal-state probe
        self.recorder.drain(&bus);
        self.feed_back_measurements(handles);
        self.release_finished_image_pins(handles);
        self.report(handles, 0.0)
    }

    /// One live-plane sweep: sample the registry's cumulative histograms
    /// and the cluster's staging totals into the rolling windows, then
    /// tick the SLO watchdog. The cluster totals are read *before* the
    /// plane guard (`Cluster` never nests under `Obs`), and fired alerts
    /// are published on the bus *after* the guard drops — the same
    /// collect-then-publish shape as every other publisher in this
    /// service.
    fn observe_plane(&self) {
        let now_ms = self.recorder.now_us() / 1_000;
        let staging = self.cluster.staging_totals();
        let fired = {
            let mut plane = lock_or_recover(&self.plane);
            let LivePlane { windows, watchdog } = &mut *plane;
            windows.staging_hits.sample(now_ms, staging.hits);
            windows.staging_misses.sample(now_ms, staging.misses);
            windows.sample_registry(now_ms, crate::obs::metrics::global());
            watchdog.tick(now_ms, windows)
        };
        for alert in &fired {
            eprintln!(
                "slo-alert: {} measured {:.6} against {:.6} (burn {:.2})",
                alert.kind.name(),
                alert.measured,
                alert.threshold,
                alert.burn
            );
            self.cluster.bus().publish(alert.event());
        }
    }

    /// Rolling-window gauge lines for `/metrics`, appended after the
    /// lifetime exposition (which stays byte-identical).
    pub fn window_gauges(&self) -> String {
        let now_ms = self.recorder.now_us() / 1_000;
        lock_or_recover(&self.plane).windows.render_gauges(now_ms)
    }

    /// The `/alerts` body: the watchdog's fired-alert log plus its
    /// budget table, as JSON.
    pub fn alerts_json(&self) -> String {
        lock_or_recover(&self.plane)
            .watchdog
            .alerts_json()
            .to_string_pretty()
    }

    /// The `/summary` body: the recorder's trace summary (per-phase
    /// percentiles + per-job critical paths) as JSON.
    pub fn summary_json(&self) -> String {
        let set = self.recorder.finish();
        crate::obs::export::summarise(&set)
            .to_json()
            .to_string_pretty()
    }

    /// The `/shards` body: per-shard queue depth, slot occupancy, and
    /// staging counters, as JSON.
    pub fn shards_json(&self) -> String {
        let arr: Vec<Json> = self
            .cluster
            .shard_snapshots()
            .iter()
            .map(|s| {
                let mut o = Json::obj();
                o.set("shard", Json::from(s.shard));
                o.set("running", Json::from(s.running));
                o.set("queued", Json::from(s.queued));
                o.set("peak_running", Json::from(s.peak_running));
                o.set("slot_capacity", Json::from(s.slot_capacity));
                o.set("migrations_in", Json::Num(s.migrations_in as f64));
                let mut st = Json::obj();
                st.set("hits", Json::Num(s.staging.hits as f64));
                st.set("misses", Json::Num(s.staging.misses as f64));
                st.set("bytes", Json::Num(s.staging.bytes as f64));
                st.set("simulated_secs", Json::Num(s.staging.simulated_secs));
                st.set("evictions", Json::Num(s.staging.evictions as f64));
                o.set("staging", st);
                let mut d = Json::obj();
                d.set("shard_hits", Json::Num(s.data.shard_hits as f64));
                d.set("shard_misses", Json::Num(s.data.shard_misses as f64));
                d.set("node_hits", Json::Num(s.data.node_hits as f64));
                d.set("node_misses", Json::Num(s.data.node_misses as f64));
                d.set("bytes_moved", Json::Num(s.data.bytes_moved as f64));
                d.set("simulated_secs", Json::Num(s.data.simulated_secs));
                d.set("evictions", Json::Num(s.data.evictions as f64));
                o.set("data", d);
                o
            })
            .collect();
        let mut j = Json::obj();
        j.set("shards", Json::Arr(arr));
        j.to_string_pretty()
    }

    /// The HTTP plane's route providers over this service (what
    /// `serve-batch --listen` binds): lifetime exposition + rolling
    /// gauges at `/metrics`, the recorder summary at `/summary`, shard
    /// snapshots at `/shards`, the watchdog log at `/alerts`.
    pub fn plane_state(self: &Arc<Self>) -> PlaneState {
        let metrics = Arc::clone(self);
        let summary = Arc::clone(self);
        let shards = Arc::clone(self);
        let alerts = Arc::clone(self);
        PlaneState {
            metrics: Arc::new(move || {
                let mut out = crate::obs::metrics::global().render_prometheus();
                out.push_str(&metrics.window_gauges());
                out
            }),
            summary: Some(Arc::new(move || summary.summary_json())),
            shards: Some(Arc::new(move || shards.shards_json())),
            alerts: Some(Arc::new(move || alerts.alerts_json())),
        }
    }

    /// Release the build-store image pin of every batch job observed
    /// terminal (pinned at dispatch in `plan_and_dispatch`): the
    /// reference-pinned-eviction contract is "never GC what a queued or
    /// running job still points at" — finished jobs stop pointing.
    fn release_finished_image_pins(&self, handles: &[PlanHandle]) {
        // collect-then-release: candidates are gathered under the set
        // lock, but the cluster probe and the registry unpin run with it
        // dropped — releasing pins must never hold a PerfModel-family
        // guard across Cluster/Registry work (lock-rank discipline)
        let candidates: Vec<(ClusterJobId, String)> = {
            let unpinned = lock_or_recover(&self.unpinned);
            handles
                .iter()
                .filter_map(|h| {
                    let out = h.outcome.as_ref()?;
                    let (Ok(plan), Some(id)) = (&out.plan, out.job_id) else {
                        return None;
                    };
                    (!unpinned.contains(&id)).then(|| (id, plan.profile.image_tag()))
                })
                .collect()
        };
        for (id, tag) in candidates {
            // unknown id (migrated bookkeeping hiccup) counts as finished
            if self.cluster.job_terminal(id).unwrap_or(true) {
                self.registry.unpin_image(&tag);
                lock_or_recover(&self.unpinned).insert(id);
            }
        }
    }

    /// Close the performance-model loop: for every newly-completed job in
    /// the batch, derive the features its plan was predicted from and
    /// record the *measured* wall time. All new records of a sweep share
    /// one refit (equivalent to per-record [`PerfModel::observe`] — only
    /// the final coefficients are ever read — at a fraction of the
    /// least-squares work). The refreshed model is persisted when it is
    /// file-backed. Reads outcomes non-blockingly, so it is safe to call
    /// while planner workers are still reporting.
    ///
    /// Locking: new measurements are collected under the per-shard server
    /// locks (taken one at a time via the cluster's job map), then the
    /// refit + disk write run under the model lock alone — scheduling
    /// passes never stall behind least squares or the history file. No
    /// code path in this service holds a shard lock and the model lock at
    /// once.
    fn feed_back_measurements(&self, handles: &[PlanHandle]) {
        let (fresh, waits, errs): (Vec<Record>, Vec<f64>, Vec<f64>) = {
            let mut fed = lock_or_recover(&self.fed_back);
            let mut fresh = Vec::new();
            let mut waits = Vec::new();
            let mut errs = Vec::new();
            for h in handles.iter() {
                let Some(out) = h.outcome.as_ref() else { continue };
                let (Ok(plan), Some(id)) = (&out.plan, out.job_id) else {
                    continue;
                };
                if fed.contains(&id) {
                    continue;
                }
                let Ok(measured) = self.cluster.with_job(id, |rec| {
                    match &rec.state {
                        JobState::Completed { wall_secs, .. } => Some((
                            *wall_secs,
                            rec.queue_wait_secs,
                            rec.script.payload.train_config(),
                        )),
                        _ => None,
                    }
                }) else {
                    continue;
                };
                let Some((measured_secs, wait_secs, cfg)) = measured else { continue };
                let Ok(wl) = self.manifest.workload(plan.profile.workload) else {
                    continue;
                };
                fresh.push(Record {
                    image: plan.profile.image_tag(),
                    workload: plan.profile.workload.to_string(),
                    features: Features::derive(&plan.profile, wl, &cfg),
                    measured_secs,
                });
                // queue wait feeds the model's SEPARATE wait target
                if let Some(w) = wait_secs {
                    waits.push(w);
                }
                // the plane's model-error window gets |signed error|%
                if let Some(pred) = plan.predicted_secs.filter(|p| *p > 0.0) {
                    errs.push(((measured_secs - pred) / pred * 100.0).abs());
                }
                fed.insert(id);
            }
            (fresh, waits, errs)
        };
        // the live plane's model-error window sees the same fresh
        // measurements; scoped so the refit below never runs under an
        // Obs-ranked guard
        if !errs.is_empty() {
            let now_ms = self.recorder.now_us() / 1_000;
            let mut plane = lock_or_recover(&self.plane);
            for e in &errs {
                plane.windows.model_abs_err_pct.observe(now_ms, *e);
            }
        }
        if fresh.is_empty() && waits.is_empty() {
            return;
        }
        let mut model = write_or_recover(&self.model);
        for w in waits {
            model.observe_wait(w);
        }
        if !fresh.is_empty() {
            model.history.extend(fresh);
            model.fit();
        }
        if let Err(e) = model.save() {
            eprintln!("service: persisting model history failed: {e:#}");
        }
    }

    /// Submit + await + time a batch: the serve-batch entry point.
    pub fn run_batch(
        &self,
        reqs: Vec<BatchRequest>,
        cfg: &TrainConfig,
        on_poll: impl FnMut(&ClusterScheduler),
    ) -> BatchReport {
        let sw = Stopwatch::start();
        let mut handles = self.submit_many(reqs, cfg, true);
        let mut report = self.await_batch(&mut handles, on_poll);
        report.makespan_secs = sw.elapsed_secs();
        report
    }

    fn report(&self, handles: &mut [PlanHandle], makespan_secs: f64) -> BatchReport {
        // model guard dropped before any shard lock: no code path in this
        // service holds both at once (see feed_back_measurements)
        let model_r2 = {
            let model = read_or_recover(&self.model);
            model.is_trained().then_some(model.r2)
        };
        let mut jobs = Vec::with_capacity(handles.len());
        for h in handles.iter_mut() {
            let label = h.label.clone();
            let out = h.wait();
            let summary = match &out.plan {
                Err(e) => JobSummary {
                    label,
                    image: None,
                    job_id: None,
                    state: 'E',
                    queue_wait_secs: None,
                    run_secs: None,
                    shard: None,
                    node: None,
                    predicted_secs: None,
                    predicted_wait_secs: None,
                    io_secs: None,
                    io_stall_secs: None,
                    error: Some(format!("{e:#}")),
                },
                Ok(plan) => {
                    let image = Some(plan.profile.image_tag());
                    let looked_up = out.job_id.and_then(|id| {
                        let shard = self.cluster.shard_of(id);
                        self.cluster
                            .with_job(id, |rec| {
                                let error = match &rec.state {
                                    JobState::Failed { error, .. } => Some(error.clone()),
                                    _ => None,
                                };
                                let io = match &rec.state {
                                    JobState::Completed { run, .. }
                                        if run.report.io_secs > 0.0 =>
                                    {
                                        Some((
                                            run.report.io_secs,
                                            run.report.io_stall_secs,
                                        ))
                                    }
                                    _ => None,
                                };
                                (
                                    rec.state.code(),
                                    rec.queue_wait_secs,
                                    rec.state.wall_secs(),
                                    rec.node,
                                    io,
                                    error,
                                )
                            })
                            .ok()
                            .map(|info| (id, shard, info))
                    });
                    match looked_up {
                        None => JobSummary {
                            label,
                            image,
                            job_id: None,
                            state: 'P',
                            queue_wait_secs: None,
                            run_secs: None,
                            shard: None,
                            node: None,
                            predicted_secs: plan.predicted_secs,
                            predicted_wait_secs: plan.predicted_wait_secs,
                            io_secs: None,
                            io_stall_secs: None,
                            error: None,
                        },
                        Some((
                            id,
                            shard,
                            (state, queue_wait_secs, run_secs, node, io, error),
                        )) => JobSummary {
                            label,
                            image,
                            job_id: Some(id),
                            state,
                            queue_wait_secs,
                            run_secs,
                            shard,
                            node,
                            predicted_secs: plan.predicted_secs,
                            predicted_wait_secs: plan.predicted_wait_secs,
                            io_secs: io.map(|(i, _)| i),
                            io_stall_secs: io.map(|(_, s)| s),
                            error,
                        },
                    }
                }
            };
            jobs.push(summary);
        }
        let cluster_report = self.cluster_report(&jobs);
        let mut report = BatchReport::from_jobs(
            jobs,
            makespan_secs,
            self.cluster.peak_running_sum(),
            self.registry.build_stats(),
            model_r2,
        );
        report.cluster = Some(cluster_report);
        report
    }

    /// Per-shard breakdown of a batch (tentpole: shard-aware reporting).
    fn cluster_report(&self, jobs: &[JobSummary]) -> ClusterReport {
        let snaps = self.cluster.shard_snapshots();
        let shards = snaps
            .iter()
            .map(|snap| {
                let mine: Vec<&JobSummary> = jobs
                    .iter()
                    .filter(|j| j.shard == Some(snap.shard))
                    .collect();
                let completed = mine.iter().filter(|j| j.state == 'C').count();
                // span from each job's submission to its finish; batch
                // submissions land ~together, so the max approximates the
                // shard's slice of the batch makespan
                let makespan_secs = mine
                    .iter()
                    .map(|j| {
                        j.queue_wait_secs.unwrap_or(0.0) + j.run_secs.unwrap_or(0.0)
                    })
                    .fold(0.0, f64::max);
                let busy_secs: f64 = mine
                    .iter()
                    .filter(|j| j.state == 'C')
                    .filter_map(|j| j.run_secs)
                    .sum();
                let capacity_secs = makespan_secs * snap.slot_capacity as f64;
                // mean IO-overlap over this shard's completed data jobs
                let io: Vec<(f64, f64)> = mine
                    .iter()
                    .filter_map(|j| Some((j.io_secs?, j.io_stall_secs?)))
                    .collect();
                let io_overlap = crate::data::overlap_ratio(
                    io.iter().map(|(i, _)| i).sum(),
                    io.iter().map(|(_, s)| s).sum(),
                );
                ShardReport {
                    shard: snap.shard,
                    jobs: mine.len(),
                    completed,
                    makespan_secs,
                    busy_secs,
                    utilisation: if capacity_secs > 0.0 {
                        (busy_secs / capacity_secs).min(1.0)
                    } else {
                        0.0
                    },
                    peak_running: snap.peak_running,
                    migrations_in: snap.migrations_in,
                    staging: snap.staging.clone(),
                    data: snap.data.clone(),
                    io_overlap,
                }
            })
            .collect();
        ClusterReport {
            router: self.cluster.router().to_string(),
            rebalance: self.cluster.rebalance_mode().to_string(),
            shards,
            migrations: self.cluster.migrations(),
            elastic_migrations: self.cluster.elastic_migrations(),
            staging_totals: self.cluster.staging_totals(),
            data_totals: self.cluster.data_totals(),
        }
    }
}

#[allow(clippy::too_many_arguments)] // the service's full planning context
fn plan_and_dispatch(
    registry: &RegistryHandle,
    model: &RwLock<PerfModel>,
    manifest: &Manifest,
    catalog: &DatasetCatalog,
    cluster: &Arc<ClusterScheduler>,
    recorder: &Recorder,
    req: &BatchRequest,
    cfg: &TrainConfig,
    dispatch: bool,
) -> PlanOutcome {
    // snapshot the model per request: planning (which may block on a
    // container build) runs lock-free, and later requests in a batch see
    // coefficients refreshed by earlier completions' feedback. The read
    // lock means a whole batch of planners can snapshot concurrently.
    let model = read_or_recover(model).clone();
    let plan_start = recorder.now_us();
    let plan = match plan_deployment(registry, &model, manifest, catalog, &req.dsl, cfg) {
        Ok(p) => p,
        Err(e) => {
            return PlanOutcome {
                plan: Err(e),
                job_id: None,
            }
        }
    };
    let plan_end = recorder.now_us();
    let job_id = if dispatch {
        // route to a shard, stage the bundle (and the declared dataset)
        // into its local stores, qsub
        match cluster.submit(
            plan.script.clone(),
            &plan.profile.image_tag(),
            &plan.image.digest,
            &plan.image.dir,
            plan.dataset.as_ref(),
        ) {
            Ok(id) => {
                // reference-pin the bundle against store GC while this
                // job lives (released when it is observed terminal)
                registry.pin_image(&plan.profile.image_tag());
                // the cluster-global job id exists only now, so the
                // planning span (profile selection + container build,
                // which runs on the service host: shard 0 by convention)
                // is recorded retroactively under it
                recorder.record_span(id, "plan", plan_start, plan_end, 0);
                Some(id)
            }
            Err(e) => {
                return PlanOutcome {
                    plan: Err(e.context(format!("dispatching plan for {}", req.label))),
                    job_id: None,
                }
            }
        }
    } else {
        None
    };
    PlanOutcome {
        plan: Ok(plan),
        job_id,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn store(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("modak_service_tests").join(name);
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// A manifest with no workloads: planning succeeds up to the build,
    /// then fails deterministically — enough to exercise the queue
    /// plumbing and the digest-keyed failure cache without artifacts.
    fn empty_manifest() -> Manifest {
        Manifest {
            dir: PathBuf::from("artifacts-not-needed"),
            workloads: Default::default(),
            artifacts: Default::default(),
        }
    }

    fn dsl(framework: &str, version: &str) -> Optimisation {
        Optimisation::parse(&format!(
            r#"{{"app_type": "ai_training",
                "ai_training": {{"{framework}": {{"version": "{version}"}}}}}}"#
        ))
        .unwrap()
    }

    /// Satellite bugfix: failed jobs' wall time used to inflate
    /// `serial_sum_secs` while `completed()` counted only 'C' jobs,
    /// overstating the reported speedup. Both must agree on the job set.
    #[test]
    fn serial_sum_counts_completed_jobs_only() {
        let j = |state: char, run: Option<f64>, pred: Option<f64>| JobSummary {
            label: "j".into(),
            image: None,
            job_id: Some(1),
            state,
            queue_wait_secs: Some(1.0),
            run_secs: run,
            shard: Some(0),
            node: None,
            predicted_secs: pred,
            predicted_wait_secs: Some(0.8),
            io_secs: None,
            io_stall_secs: None,
            error: None,
        };
        let report = BatchReport::from_jobs(
            vec![
                j('C', Some(2.0), Some(1.6)),
                j('F', Some(50.0), Some(1.0)), // walltime-killed: excluded
                j('C', Some(3.0), None),
                j('E', None, None),
            ],
            2.5,
            2,
            crate::container::BuildStats::default(),
            Some(0.9),
        );
        assert_eq!(report.completed(), 2);
        assert!(
            (report.serial_sum_secs - 5.0).abs() < 1e-9,
            "failed jobs must not inflate the serial sum: {}",
            report.serial_sum_secs
        );
        assert!((report.throughput_jobs_per_sec() - 0.8).abs() < 1e-9);
        // predicted-vs-measured error: completed jobs with predictions only
        assert_eq!(report.jobs[0].pct_error().map(f64::round), Some(25.0));
        assert_eq!(report.jobs[1].pct_error(), None, "failed job has no error row");
        assert_eq!(report.jobs[2].pct_error(), None, "no prediction, no error row");
        assert!((report.mean_abs_pct_error().unwrap() - 25.0).abs() < 1e-9);
        // the queue-wait target is scored in its OWN error column
        assert_eq!(report.jobs[0].wait_pct_error().map(f64::round), Some(25.0));
        assert_eq!(report.jobs[1].wait_pct_error(), None, "failed job: no wait row");
        assert!((report.mean_abs_wait_pct_error().unwrap() - 25.0).abs() < 1e-9);
        let rendered = report.render();
        assert!(rendered.contains("prediction mean abs err"), "{rendered}");
        assert!(rendered.contains("pred(s)"), "{rendered}");
        assert!(rendered.contains("wpred(s)"), "{rendered}");
        assert!(rendered.contains("werr%"), "{rendered}");
        assert!(rendered.contains("queue-wait mean abs err"), "{rendered}");
    }

    #[test]
    fn submit_many_preserves_order_and_reports_errors() {
        let service = DeploymentService::new(
            store("order"),
            empty_manifest(),
            PerfModel::new(),
            &ServiceConfig::default(),
        );
        let reqs = vec![
            BatchRequest { label: "a".into(), dsl: dsl("pytorch", "1.14") },
            BatchRequest { label: "b".into(), dsl: dsl("tensorflow", "2.1") },
            BatchRequest { label: "c".into(), dsl: dsl("pytorch", "1.14") },
        ];
        let cfg = TrainConfig { epochs: 1, steps_per_epoch: 1, seed: 0 };
        let mut handles = service.submit_many(reqs, &cfg, true);
        assert_eq!(handles.len(), 3);
        for (i, h) in handles.iter_mut().enumerate() {
            assert_eq!(h.index, i);
            let label = h.label.clone();
            // without artifacts every build fails; the outcome must be a
            // clean error, never a hang or a dispatched job
            let out = h.wait();
            assert!(out.plan.is_err(), "{label}: {:?}", out.plan);
            assert!(out.job_id.is_none());
        }
        assert_eq!(handles[0].label, "a");
        assert_eq!(handles[2].label, "c");
        // identical requests a and c share one (failed) build slot:
        // the digest-keyed cache deduplicated the second attempt
        let stats = service.registry().build_stats();
        assert_eq!(stats.builds, 0);
        assert!(stats.cache_hits >= 1, "{stats:?}");
    }

    #[test]
    fn await_batch_returns_report_for_undispatched_batch() {
        let service = DeploymentService::new(
            store("report"),
            empty_manifest(),
            PerfModel::new(),
            &ServiceConfig { planner_workers: 2, ..ServiceConfig::default() },
        );
        let cfg = TrainConfig { epochs: 1, steps_per_epoch: 1, seed: 0 };
        let mut handles = service.submit_many(
            vec![BatchRequest { label: "only".into(), dsl: dsl("mxnet", "2.0") }],
            &cfg,
            false,
        );
        let mut polls = 0;
        let report = service.await_batch(&mut handles, |_cluster| polls += 1);
        assert_eq!(report.jobs.len(), 1);
        assert_eq!(report.jobs[0].state, 'E'); // build failed without artifacts
        assert!(report.jobs[0].error.is_some());
        assert!(polls >= 1);
        assert_eq!(report.completed(), 0);
        // render() must not panic on degenerate reports
        assert!(report.render().contains("makespan"));
    }

    /// Satellite (PR 7): concurrent publishers overrun the bounded event
    /// ring before the batch is awaited — `drain_since` must report the
    /// overflow, and `await_batch` (whose own cursor starts at 0, so its
    /// first drain sees the same overrun) must fall back to the full
    /// `poll()` sweep and still resolve every handle.
    #[test]
    fn await_batch_survives_event_ring_overflow_via_full_poll_fallback() {
        use crate::util::sync::SchedEvent;
        let service = DeploymentService::new(
            store("overflow"),
            empty_manifest(),
            PerfModel::new(),
            &ServiceConfig { planner_workers: 2, ..ServiceConfig::default() },
        );
        // 4 publishers x 2000 events into a 4096-slot ring: over half the
        // sequence space is evicted before anyone drains
        let bus = service.cluster().bus();
        let publishers: Vec<_> = (0..4u64)
            .map(|t| {
                let b = Arc::clone(&bus);
                std::thread::spawn(move || {
                    for j in 0..2_000u64 {
                        b.publish(SchedEvent::Submit { shard: 0, job: t * 10_000 + j });
                    }
                })
            })
            .collect();
        for p in publishers {
            p.join().unwrap();
        }
        let drained = bus.drain_since(0);
        assert_eq!(drained.seen, 8_000);
        assert!(
            drained.missed > 0,
            "8000 publishes must overrun the ring: {:?}",
            (drained.seen, drained.missed, drained.events.len())
        );
        // the batch still resolves end-to-end: the overflow forces the
        // full-sweep backstop instead of a targeted pass, and no handle is
        // lost or left hanging
        let cfg = TrainConfig { epochs: 1, steps_per_epoch: 1, seed: 0 };
        let mut handles = service.submit_many(
            vec![BatchRequest { label: "x".into(), dsl: dsl("pytorch", "1.14") }],
            &cfg,
            true,
        );
        let report = service.await_batch(&mut handles, |_| {});
        assert_eq!(report.jobs.len(), 1);
        assert_eq!(report.jobs[0].state, 'E'); // build failed without artifacts
        // the cursor caught up: a fresh drain from the returned position
        // is clean (nothing further was missed)
        let after = bus.drain_since(drained.seen);
        assert_eq!(after.missed, 0, "{:?}", (after.seen, after.events.len()));
    }

    /// Tentpole smoke test (no artifacts needed): a multi-shard service
    /// boots a heterogeneous cluster, routes through the configured
    /// router, and reports per-shard stats even for a batch that failed at
    /// planning.
    #[test]
    fn multi_shard_service_reports_cluster_shape() {
        let service = DeploymentService::new(
            store("shards"),
            empty_manifest(),
            PerfModel::new(),
            &ServiceConfig {
                shards: 3,
                router: ShardRouter::PerfAware,
                ..ServiceConfig::default()
            },
        );
        assert_eq!(service.cluster().shard_count(), 3);
        assert_eq!(service.cluster().router(), ShardRouter::PerfAware);
        let cfg = TrainConfig { epochs: 1, steps_per_epoch: 1, seed: 0 };
        let mut handles = service.submit_many(
            vec![BatchRequest { label: "x".into(), dsl: dsl("pytorch", "1.14") }],
            &cfg,
            true,
        );
        let report = service.await_batch(&mut handles, |_| {});
        let cluster = report.cluster.as_ref().expect("cluster section present");
        assert_eq!(cluster.shards.len(), 3);
        assert_eq!(cluster.router, "perf-aware");
        assert_eq!(cluster.migrations, 0);
        // per-shard job counts sum to the batch's dispatched jobs (zero
        // here: planning failed without artifacts)
        assert_eq!(cluster.shards.iter().map(|s| s.jobs).sum::<usize>(), 0);
        let rendered = report.render();
        assert!(rendered.contains("cluster: 3 shards"), "{rendered}");
        assert!(rendered.contains("router perf-aware"), "{rendered}");
        assert!(rendered.contains("rebalance queued"), "{rendered}");
    }

    /// Tentpole: every live-plane surface renders from a fresh service —
    /// valid JSON on the JSON routes, exposition-parseable gauges on the
    /// windowed metrics — and `await_batch` ticks the plane without
    /// firing alerts on an idle service.
    #[test]
    fn live_plane_surfaces_render_and_stay_quiet_when_idle() {
        let service = Arc::new(DeploymentService::new(
            store("plane"),
            empty_manifest(),
            PerfModel::new(),
            &ServiceConfig::default(),
        ));
        let cfg = TrainConfig { epochs: 1, steps_per_epoch: 1, seed: 0 };
        let mut handles = service.submit_many(
            vec![BatchRequest { label: "x".into(), dsl: dsl("pytorch", "1.14") }],
            &cfg,
            true,
        );
        // await_batch runs observe_plane every sweep
        let _ = service.await_batch(&mut handles, |_| {});
        let alerts = Json::parse(&service.alerts_json()).unwrap();
        assert_eq!(alerts.get("count").as_usize(), Some(0), "idle service must not alert");
        assert_eq!(alerts.get("budgets").as_arr().map(Vec::len), Some(4));
        let shards = Json::parse(&service.shards_json()).unwrap();
        assert_eq!(shards.get("shards").as_arr().map(Vec::len), Some(1));
        let snap = &shards.get("shards").as_arr().unwrap()[0];
        assert_eq!(snap.get("shard").as_usize(), Some(0));
        assert!(snap.get("staging").get("hits").as_f64().is_some());
        let summary = Json::parse(&service.summary_json()).unwrap();
        assert!(summary.get("makespan_s").as_f64().is_some());
        // windowed gauges speak the exposition dialect
        let gauges = crate::obs::metrics::parse_exposition(&service.window_gauges());
        assert!(
            gauges.keys().any(|k| k.starts_with("modak_window_queue_wait_seconds_p99")),
            "{gauges:?}"
        );
        // the wired plane serves lifetime + windowed series on one scrape
        let plane = service.plane_state();
        let scraped = crate::obs::metrics::parse_exposition(&(plane.metrics)());
        assert!(scraped.contains_key("modak_jobs_submitted"));
        assert!(scraped.keys().any(|k| k.starts_with("modak_window_")));
        assert!(plane.summary.is_some() && plane.shards.is_some() && plane.alerts.is_some());
    }

    /// Satellite: `--policy-shard N=<policy>` overrides land on the named
    /// shard; unlisted shards keep the default, out-of-range indices are
    /// ignored; `--rebalance elastic` reaches the cluster.
    #[test]
    fn per_shard_policies_and_rebalance_mode_are_plumbed() {
        let service = DeploymentService::new(
            store("shard_policies"),
            empty_manifest(),
            PerfModel::new(),
            &ServiceConfig {
                shards: 3,
                policy: SchedulePolicy::Reservation,
                shard_policies: vec![
                    (1, SchedulePolicy::Sjf),
                    (99, SchedulePolicy::Fifo), // out of range: ignored
                ],
                rebalance: RebalanceMode::Elastic,
                ..ServiceConfig::default()
            },
        );
        let cluster = service.cluster();
        assert_eq!(cluster.rebalance_mode(), RebalanceMode::Elastic);
        assert_eq!(
            cluster.with_shard(0, |s| s.policy()),
            SchedulePolicy::Reservation
        );
        assert_eq!(cluster.with_shard(1, |s| s.policy()), SchedulePolicy::Sjf);
        assert_eq!(
            cluster.with_shard(2, |s| s.policy()),
            SchedulePolicy::Reservation
        );
    }
}
