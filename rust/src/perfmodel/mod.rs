//! The MODAK performance model (paper §III): "performance models are
//! developed by running standard benchmarks across different configurations
//! ... and then building a linear statistical model. This model informs
//! MODAK about how the application parameters affect the performance."
//!
//! Features are *mechanistic* — derived from what a container variant will
//! actually do (dispatches per step, bytes across the host per step, kernel
//! quality, compiles per epoch) — so the linear model generalises across
//! epoch/step counts instead of memorising (image, time) pairs.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::frameworks::Profile;
use crate::runtime::{Manifest, VariantBinding, WorkloadSpec};
use crate::trainer::TrainConfig;
use crate::util::json::Json;
use crate::util::stats::{least_squares, r_squared};

/// Mechanistic description of one benchmark run.
#[derive(Debug, Clone, PartialEq)]
pub struct Features {
    /// Total optimisation steps (epochs * steps_per_epoch).
    pub steps: f64,
    /// PJRT dispatches over the run.
    pub dispatches: f64,
    /// Gigabytes crossing the host boundary over the run.
    pub gbytes: f64,
    /// XLA compilations during the run (recompile-per-epoch profiles).
    pub compiles: f64,
    /// Extra arithmetic from the kernel-quality gap, in step units:
    /// steps * penalty(kernel). naive conv ~ 9x, generic ~ 1.5x, ref 1x.
    pub kernel_steps: f64,
}

impl Features {
    pub fn vector(&self) -> Vec<f64> {
        vec![
            1.0,
            self.steps,
            self.dispatches,
            self.gbytes,
            self.compiles,
            self.kernel_steps,
        ]
    }

    pub const DIM: usize = 6;

    /// Derive features for running `profile` under `cfg`, statically from
    /// the manifest (no execution).
    pub fn derive(profile: &Profile, wl: &WorkloadSpec, cfg: &TrainConfig) -> Features {
        let steps = (cfg.epochs * cfg.steps_per_epoch) as f64;
        let binding = wl.variants.get(profile.variant);
        let (disp_per_step, stage_crossings) = match binding {
            Some(VariantBinding::Fused { .. }) | None => (1.0, 0.0),
            Some(VariantBinding::Staged { fwd, bwd }) => {
                ((fwd.len() + bwd.len() + 1) as f64, (fwd.len() + bwd.len()) as f64)
            }
            Some(VariantBinding::ThreeStage { .. }) => (3.0, 2.0),
        };
        // bytes: params make a round trip each step; activations cross per
        // stage boundary; batch goes up once per step
        let param_bytes = (wl.param_count * 4) as f64;
        let batch_bytes = (wl.input.size_bytes() + wl.labels.size_bytes()) as f64;
        let act_bytes = batch_bytes * stage_crossings; // rough, intentional
        let per_step = 2.0 * param_bytes + batch_bytes + act_bytes;
        let kernel_penalty = kernel_penalty_of(profile.variant);
        let compiles = if profile.policy.recompile_each_epoch {
            cfg.epochs as f64
        } else {
            0.0
        };
        Features {
            steps,
            dispatches: steps * disp_per_step,
            gbytes: steps * per_step / 1e9,
            compiles,
            kernel_steps: steps * (kernel_penalty - 1.0),
        }
    }
}

/// Relative arithmetic cost of a variant's kernel set (vs the tuned ref).
///
/// Pallas is matched first: an interpret-mode Pallas variant dominates any
/// other marker in its name (`staged_pallas_naive` is 40x interpreted, not
/// a 9x naive kernel), so the check order is cost-descending.
pub fn kernel_penalty_of(variant: &str) -> f64 {
    if variant.contains("pallas") {
        // interpret-mode Pallas on CPU: numerics-only, heavily interpreted
        40.0
    } else if variant.contains("naive") {
        9.0
    } else if variant.contains("generic") {
        1.5
    } else {
        1.0
    }
}

/// Fold per-step dataset IO into a compute-only wall-time prediction,
/// assuming the double-buffered prefetcher overlaps IO with compute: IO
/// slower than compute stalls the step loop by the difference, IO faster
/// hides entirely. With `steps` steps at `compute/steps` seconds each,
/// the expected stall is `max(0, io_per_step - compute_per_step)` per
/// step — so total = compute + steps * stall.
pub fn io_adjusted_secs(compute_secs: f64, io_secs_per_step: f64, steps: f64) -> f64 {
    if steps <= 0.0 || io_secs_per_step <= 0.0 {
        return compute_secs;
    }
    let compute_per_step = (compute_secs / steps).max(0.0);
    compute_secs + steps * (io_secs_per_step - compute_per_step).max(0.0)
}

/// One observed benchmark run.
#[derive(Debug, Clone)]
pub struct Record {
    pub image: String,
    pub workload: String,
    pub features: Features,
    pub measured_secs: f64,
}

/// Cap on retained queue-wait observations: the wait target is a
/// scheduler property that drifts with load, so only a recent window is
/// worth fitting (oldest observations roll off).
pub const WAIT_HISTORY_CAP: usize = 512;

/// The trained model + its history store.
///
/// Two *separate* observe/fit targets (scheduler-refinements open item):
///
/// * **run time** — a function of the job's mechanistic features, fit by
///   least squares over [`Record`]s ([`Self::observe`] / [`Self::fit`] /
///   [`Self::predict`]);
/// * **queue wait** — a property of the scheduler's load, not of the job,
///   so it gets its own estimator: a rolling window of measured waits
///   ([`Self::observe_wait`]) predicting via the window mean
///   ([`Self::predict_wait`]).
///
/// Folding waits into the run-time regression would bias both; splitting
/// them lets the batch report show a run error AND a wait error column.
#[derive(Clone)]
pub struct PerfModel {
    pub history: Vec<Record>,
    /// Rolling window of measured queue waits (seconds), newest last.
    pub wait_history: Vec<f64>,
    beta: Option<Vec<f64>>,
    pub r2: f64,
    path: Option<PathBuf>,
}

impl PerfModel {
    pub fn new() -> PerfModel {
        PerfModel {
            history: Vec::new(),
            wait_history: Vec::new(),
            beta: None,
            r2: 0.0,
            path: None,
        }
    }

    /// Open (or create) a model backed by a history file.
    pub fn open(path: impl AsRef<Path>) -> Result<PerfModel> {
        let path = path.as_ref().to_path_buf();
        let mut model = PerfModel::new();
        model.path = Some(path.clone());
        if path.exists() {
            let text = std::fs::read_to_string(&path)?;
            let j = Json::parse(&text).map_err(|e| anyhow!("history: {e}"))?;
            for r in j.get("records").as_arr().unwrap_or(&[]) {
                let f = r.get("features");
                model.history.push(Record {
                    image: r.get("image").as_str().unwrap_or("").to_string(),
                    workload: r.get("workload").as_str().unwrap_or("").to_string(),
                    features: Features {
                        steps: f.get("steps").as_f64().unwrap_or(0.0),
                        dispatches: f.get("dispatches").as_f64().unwrap_or(0.0),
                        gbytes: f.get("gbytes").as_f64().unwrap_or(0.0),
                        compiles: f.get("compiles").as_f64().unwrap_or(0.0),
                        kernel_steps: f.get("kernel_steps").as_f64().unwrap_or(0.0),
                    },
                    measured_secs: r.get("measured_secs").as_f64().unwrap_or(0.0),
                });
            }
            for w in j.get("waits").as_arr().unwrap_or(&[]) {
                if let Some(secs) = w.as_f64() {
                    model.wait_history.push(secs);
                }
            }
            model.fit();
        }
        Ok(model)
    }

    /// Record a measurement and refit.
    pub fn observe(&mut self, rec: Record) {
        self.history.push(rec);
        self.fit();
    }

    /// Record a measured queue wait (the scheduler-side target, fit
    /// separately from run time). Oldest observations roll off past
    /// [`WAIT_HISTORY_CAP`].
    pub fn observe_wait(&mut self, secs: f64) {
        if secs.is_finite() && secs >= 0.0 {
            self.wait_history.push(secs);
            if self.wait_history.len() > WAIT_HISTORY_CAP {
                let drop = self.wait_history.len() - WAIT_HISTORY_CAP;
                self.wait_history.drain(..drop);
            }
        }
    }

    /// Predicted queue wait: the mean of the observed window (None until
    /// a wait has been measured).
    pub fn predict_wait(&self) -> Option<f64> {
        if self.wait_history.is_empty() {
            None
        } else {
            Some(self.wait_history.iter().sum::<f64>() / self.wait_history.len() as f64)
        }
    }

    /// Persist the history (when opened with a path).
    pub fn save(&self) -> Result<()> {
        let Some(path) = &self.path else { return Ok(()) };
        let mut records = Vec::new();
        for r in &self.history {
            let mut fj = Json::obj();
            fj.set("steps", Json::from(r.features.steps))
                .set("dispatches", Json::from(r.features.dispatches))
                .set("gbytes", Json::from(r.features.gbytes))
                .set("compiles", Json::from(r.features.compiles))
                .set("kernel_steps", Json::from(r.features.kernel_steps));
            let mut rj = Json::obj();
            rj.set("image", Json::from(r.image.as_str()))
                .set("workload", Json::from(r.workload.as_str()))
                .set("features", fj)
                .set("measured_secs", Json::from(r.measured_secs));
            records.push(rj);
        }
        let mut j = Json::obj();
        j.set("records", Json::Arr(records));
        j.set(
            "waits",
            Json::Arr(self.wait_history.iter().map(|w| Json::from(*w)).collect()),
        );
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, j.to_string_pretty())?;
        Ok(())
    }

    /// Refit the linear model; needs more observations than features.
    pub fn fit(&mut self) {
        if self.history.len() <= Features::DIM {
            self.beta = None;
            return;
        }
        let xs: Vec<Vec<f64>> = self.history.iter().map(|r| r.features.vector()).collect();
        let ys: Vec<f64> = self.history.iter().map(|r| r.measured_secs).collect();
        match least_squares(&xs, &ys) {
            Some(beta) => {
                self.r2 = r_squared(&xs, &ys, &beta);
                self.beta = Some(beta);
            }
            None => {
                // a singular system (e.g. duplicate feature rows) must not
                // leave a stale fit behind: is_trained() would lie and
                // predictions would come from coefficients the current
                // history no longer supports
                self.beta = None;
                self.r2 = 0.0;
            }
        }
    }

    pub fn is_trained(&self) -> bool {
        self.beta.is_some()
    }

    /// Predict wall-clock seconds for a feature vector.
    pub fn predict(&self, f: &Features) -> Option<f64> {
        let beta = self.beta.as_ref()?;
        Some(
            f.vector()
                .iter()
                .zip(beta)
                .map(|(a, b)| a * b)
                .sum::<f64>()
                .max(0.0),
        )
    }

    /// Predict for a profile/config pair straight from the manifest.
    pub fn predict_profile(
        &self,
        profile: &Profile,
        manifest: &Manifest,
        cfg: &TrainConfig,
    ) -> Option<f64> {
        let wl = manifest.workload(profile.workload).ok()?;
        self.predict(&Features::derive(profile, wl, cfg))
    }
}

impl Default for PerfModel {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn synth_features(rng: &mut Rng) -> Features {
        let steps = rng.range(4, 200) as f64;
        let disp = steps * rng.range(1, 9) as f64;
        Features {
            steps,
            dispatches: disp,
            gbytes: steps * rng.next_f32() as f64 * 0.1,
            compiles: rng.below(8) as f64,
            kernel_steps: steps * rng.below(3) as f64,
        }
    }

    /// Planted cost model: the linear fit must recover it and predict well.
    #[test]
    fn recovers_planted_cost_model() {
        let mut rng = Rng::new(99);
        let mut model = PerfModel::new();
        let cost = |f: &Features| {
            0.5 + 0.12 * f.steps + 0.004 * f.dispatches + 2.0 * f.gbytes
                + 1.4 * f.compiles
                + 0.09 * f.kernel_steps
        };
        for i in 0..60 {
            let f = synth_features(&mut rng);
            let secs = cost(&f) * (1.0 + 0.01 * rng.normal() as f64);
            model.observe(Record {
                image: format!("img{i}"),
                workload: "w".into(),
                features: f,
                measured_secs: secs,
            });
        }
        assert!(model.is_trained());
        assert!(model.r2 > 0.99, "r2 = {}", model.r2);
        let probe = synth_features(&mut rng);
        let pred = model.predict(&probe).unwrap();
        let want = cost(&probe);
        assert!(
            (pred - want).abs() < 0.05 * want.max(1.0),
            "pred {pred} want {want}"
        );
    }

    #[test]
    fn untrained_model_predicts_none() {
        let model = PerfModel::new();
        assert!(!model.is_trained());
        assert!(model
            .predict(&Features {
                steps: 1.0,
                dispatches: 1.0,
                gbytes: 0.0,
                compiles: 0.0,
                kernel_steps: 0.0
            })
            .is_none());
    }

    #[test]
    fn save_load_roundtrip() {
        let path = std::env::temp_dir().join("modak_perfmodel_tests/history.json");
        let _ = std::fs::remove_file(&path);
        let mut rng = Rng::new(1);
        let mut model = PerfModel::open(&path).unwrap();
        for i in 0..10 {
            model.observe(Record {
                image: format!("i{i}"),
                workload: "w".into(),
                features: synth_features(&mut rng),
                measured_secs: i as f64 + 1.0,
            });
        }
        model.observe_wait(2.0);
        model.observe_wait(4.0);
        model.save().unwrap();
        let back = PerfModel::open(&path).unwrap();
        assert_eq!(back.history.len(), 10);
        assert_eq!(back.history[3].image, "i3");
        assert!((back.history[3].measured_secs - 4.0).abs() < 1e-9);
        // the wait window persists alongside the run-time records
        assert_eq!(back.wait_history, vec![2.0, 4.0]);
        assert_eq!(back.predict_wait(), Some(3.0));
    }

    /// Satellite (scheduler refinements): queue wait is its OWN
    /// observe/fit target — measured waits never pollute the run-time
    /// regression, and the wait predictor tracks the observed window.
    #[test]
    fn wait_target_is_split_from_run_time() {
        let mut model = PerfModel::new();
        assert_eq!(model.predict_wait(), None, "no waits observed yet");
        model.observe_wait(1.0);
        model.observe_wait(3.0);
        assert_eq!(model.predict_wait(), Some(2.0));
        // wait observations do not create run-time history or train beta
        assert!(model.history.is_empty());
        assert!(!model.is_trained());
        // junk observations are rejected, the window stays clean
        model.observe_wait(-5.0);
        model.observe_wait(f64::NAN);
        assert_eq!(model.wait_history.len(), 2);
        // the window is bounded: oldest observations roll off
        for i in 0..(WAIT_HISTORY_CAP + 10) {
            model.observe_wait(i as f64);
        }
        assert_eq!(model.wait_history.len(), WAIT_HISTORY_CAP);
        // 524 total observations, last 512 kept: the window now starts at
        // the loop's i=10 observation
        assert_eq!(model.wait_history[0], 10.0, "oldest rolled off");
        assert_eq!(*model.wait_history.last().unwrap(), 521.0);
    }

    /// Tentpole (IO-aware planning): IO hidden behind compute costs
    /// nothing; IO slower than compute stalls the loop by the difference.
    #[test]
    fn io_adjustment_models_overlap() {
        // compute 10s over 10 steps (1 s/step); 0.2 s/step IO hides fully
        assert!((io_adjusted_secs(10.0, 0.2, 10.0) - 10.0).abs() < 1e-12);
        // 1.5 s/step IO: the loop is IO-bound — total = steps x io
        assert!((io_adjusted_secs(10.0, 1.5, 10.0) - 15.0).abs() < 1e-12);
        // degenerate inputs change nothing
        assert_eq!(io_adjusted_secs(7.0, 0.0, 10.0), 7.0);
        assert_eq!(io_adjusted_secs(7.0, 1.0, 0.0), 7.0);
    }

    #[test]
    fn kernel_penalties_are_ordered() {
        assert!(kernel_penalty_of("staged_naive") > kernel_penalty_of("staged_generic"));
        assert!(kernel_penalty_of("fused_generic") > kernel_penalty_of("fused_ref"));
        assert_eq!(kernel_penalty_of("fused_ref"), 1.0);
        // pallas dominates every other marker in a variant name: the
        // interpret-mode penalty, not the naive-kernel one
        assert!(kernel_penalty_of("fused_pallas") > kernel_penalty_of("staged_naive"));
        assert_eq!(
            kernel_penalty_of("staged_pallas_naive"),
            kernel_penalty_of("fused_pallas")
        );
        assert_eq!(
            kernel_penalty_of("pallas_generic"),
            kernel_penalty_of("fused_pallas")
        );
    }

    /// Satellite bugfix: a fit failure (singular normal equations from
    /// duplicate feature rows) must clear the previous fit, not keep
    /// serving stale coefficients while is_trained() claims health.
    #[test]
    fn failed_refit_resets_the_model_instead_of_lying() {
        let mut rng = Rng::new(3);
        let mut model = PerfModel::new();
        for i in 0..20 {
            model.observe(Record {
                image: format!("img{i}"),
                workload: "w".into(),
                features: synth_features(&mut rng),
                measured_secs: 1.0 + i as f64,
            });
        }
        assert!(model.is_trained());
        assert!(model.r2 != 0.0);
        // replace the history with degenerate rows: dispatches is an exact
        // multiple of steps and three columns are constant zero, so the
        // normal equations are singular and least_squares returns None
        model.history.clear();
        for i in 1..=(Features::DIM + 4) {
            model.history.push(Record {
                image: format!("dup{i}"),
                workload: "w".into(),
                features: Features {
                    steps: i as f64,
                    dispatches: 2.0 * i as f64,
                    gbytes: 0.0,
                    compiles: 0.0,
                    kernel_steps: 0.0,
                },
                measured_secs: 5.0,
            });
        }
        model.fit();
        // the fit failed: the stale beta must be gone, not half-kept
        assert!(!model.is_trained(), "singular refit must untrain the model");
        assert_eq!(model.r2, 0.0);
        assert!(model
            .predict(&Features {
                steps: 1.0,
                dispatches: 2.0,
                gbytes: 0.0,
                compiles: 0.0,
                kernel_steps: 0.0,
            })
            .is_none());
    }

    /// Tentpole: online feedback. A model bootstrapped from a biased,
    /// noisy calibration sweep mispredicts; observing accurate measured
    /// batch results (what `DeploymentService` feeds back after each run)
    /// shrinks the prediction error.
    #[test]
    fn online_feedback_shrinks_prediction_error() {
        let mut rng = Rng::new(7);
        let cost = |f: &Features| {
            2.0 + 0.3 * f.steps
                + 0.01 * f.dispatches
                + 3.0 * f.gbytes
                + 0.8 * f.compiles
                + 0.05 * f.kernel_steps
        };
        let mut model = PerfModel::new();
        // bootstrap: barely enough rows, systematically 30% pessimistic
        for i in 0..(Features::DIM + 4) {
            let f = synth_features(&mut rng);
            let secs = cost(&f) * 1.3 * (1.0 + 0.05 * rng.normal() as f64);
            model.observe(Record {
                image: format!("boot{i}"),
                workload: "w".into(),
                features: f,
                measured_secs: secs,
            });
        }
        assert!(model.is_trained());
        let probes: Vec<Features> = (0..32).map(|_| synth_features(&mut rng)).collect();
        let mean_abs_rel_err = |m: &PerfModel| {
            probes
                .iter()
                .map(|f| {
                    let pred = m.predict(f).expect("trained");
                    ((pred - cost(f)) / cost(f)).abs()
                })
                .sum::<f64>()
                / probes.len() as f64
        };
        let before = mean_abs_rel_err(&model);
        // online feedback: accurate measured wall times from completed jobs
        for i in 0..60 {
            let f = synth_features(&mut rng);
            let secs = cost(&f) * (1.0 + 0.005 * rng.normal() as f64);
            model.observe(Record {
                image: format!("fb{i}"),
                workload: "w".into(),
                features: f,
                measured_secs: secs,
            });
        }
        let after = mean_abs_rel_err(&model);
        assert!(
            after < before,
            "feedback must shrink error: before {before:.4}, after {after:.4}"
        );
        assert!(after < 0.10, "error after feedback still {after:.4}");
    }
}
