//! Microbenchmark: per-step latency of every (variant, policy) combination —
//! the calibration data behind the framework-profile bindings
//! (frameworks/mod.rs) and the §Perf iteration log in EXPERIMENTS.md.
//!
//! harness=false (no criterion in the vendored set): warms up one step,
//! then reports median / mean over N timed steps.
//!
//! Usage: `cargo bench --bench step_latency -- [steps]`

use modak::executor::{ExecPolicy, TrainSession};
use modak::runtime::{Engine, Manifest};
use modak::trainer::data::Dataset;
use modak::util::stats::Summary;
use modak::util::timer::Stopwatch;

fn main() {
    let steps: usize = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .and_then(|s| s.parse().ok())
        .unwrap_or(5);

    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("step_latency bench skipped (run `make artifacts`): {e}");
            return;
        }
    };
    let engine = Engine::cpu().expect("PJRT cpu client");

    // (workload, variant, policy, what it models)
    let combos: &[(&str, &str, ExecPolicy, &str)] = &[
        ("mnist_cnn", "fused_ref", ExecPolicy::host(), "TF2.x src build"),
        ("mnist_cnn", "fused_generic", ExecPolicy::host(), "TF2.x hub binary"),
        ("mnist_cnn", "staged_ref", ExecPolicy::device(), "PyTorch src build"),
        ("mnist_cnn", "staged_generic", ExecPolicy::device(), "PyTorch/MXNet hub"),
        ("mnist_cnn", "staged_generic", ExecPolicy::host(), "TF1.x hub session"),
        ("mnist_cnn", "staged_naive", ExecPolicy::host(), "CNTK cpu"),
        ("resnet50s", "fused_ref", ExecPolicy::host(), "XLA gpu-sim"),
        ("resnet50s", "threestage_ref", ExecPolicy::host(), "TF gpu-sim src"),
        ("resnet50s", "threestage_generic", ExecPolicy::host(), "TF gpu-sim hub"),
    ];

    println!(
        "{:<11} {:<18} {:<8} {:>10} {:>10} {:>9}  models",
        "workload", "variant", "policy", "median", "mean", "compile"
    );
    for (workload, variant, policy, models) in combos {
        let mut session =
            match TrainSession::new(&engine, &manifest, workload, variant, *policy, 0, 0.05) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("{workload}/{variant}: {e:#}");
                    continue;
                }
            };
        let compile_secs = session.stats.compile_secs;
        let mut data = Dataset::for_workload(&session.workload, 7);
        // warmup (first step pays one-time costs; the paper notes the same
        // first-epoch effect)
        let (x, y) = data.next_batch();
        session.step(&x, &y).expect("warmup step");
        let mut samples = Vec::with_capacity(steps);
        for _ in 0..steps {
            let (x, y) = data.next_batch();
            let sw = Stopwatch::start();
            session.step(&x, &y).expect("timed step");
            samples.push(sw.elapsed_secs());
        }
        let s = Summary::of(&samples);
        let pol = match policy.copy {
            modak::executor::CopyPolicy::HostRoundTrip => "host",
            modak::executor::CopyPolicy::DeviceResident => "device",
        };
        println!(
            "{workload:<11} {variant:<18} {pol:<8} {:>8.1}ms {:>8.1}ms {:>8.2}s  {models}",
            s.median * 1e3,
            s.mean * 1e3,
            compile_secs
        );
    }
}
