//! Routing-strategy × rebalance-mode comparison on the deterministic
//! placement simulation (`modak::placement::sim`) — the same engine behind
//! the elastic-vs-queued CI regression, over a bigger skewed mix.
//!
//! Needs no AOT artifacts: everything is pure decision logic, so the
//! numbers are exactly reproducible on any host. Reported per
//! (strategy, mode):
//!
//! * makespan — finish time of the last job,
//! * migrations — queued moves + elastic checkpoint/restarts,
//! * regressions — times best-score migration would have lost to
//!   first-idle-fit (pinned at 0),
//! * spread — dispatches per shard.
//!
//! Run: `cargo bench --bench placement`

use modak::frameworks::Target;
use modak::placement::sim::{simulate_placement, PlacementSimJob};
use modak::placement::{PlacementStrategy, RebalanceMode};
use modak::scheduler::policy::{NodeState, SchedulePolicy};

/// A heterogeneous 3-shard cluster: wide (1 node x 3 slots), medium
/// (1 node x 2 slots), narrow (1 node x 1 slot). Wide jobs can only ever
/// run on shard 0 — the shape that makes elastic rebalancing matter.
fn shards() -> Vec<Vec<NodeState>> {
    let node = |slots: usize| NodeState {
        id: 0,
        class: Target::Cpu,
        free_slots: slots,
        total_slots: slots,
    };
    vec![vec![node(3)], vec![node(2)], vec![node(1)]]
}

/// Skewed arrival mix: long narrow jobs land first and soak up the wide
/// shard; wide (2–3 slot) jobs trickle in behind them and block.
fn job_mix() -> Vec<PlacementSimJob> {
    let mut jobs = Vec::new();
    let mut id = 0;
    // t=0 burst of long 1-slot jobs (10 epochs x 12s)
    for _ in 0..4 {
        jobs.push(PlacementSimJob {
            id,
            demand: 1,
            epochs: 10,
            epoch_secs: 12.0,
            arrive: 0.0,
        });
        id += 1;
    }
    // wide jobs arrive shortly after, already blocked behind the burst
    for (i, demand) in [(0, 3), (1, 2), (2, 2)] {
        jobs.push(PlacementSimJob {
            id,
            demand,
            epochs: 2,
            epoch_secs: 8.0,
            arrive: 2.0 + 3.0 * i as f64,
        });
        id += 1;
    }
    // a steady trickle of short 1-slot fillers
    for i in 0..6 {
        jobs.push(PlacementSimJob {
            id,
            demand: 1,
            epochs: 1,
            epoch_secs: 6.0,
            arrive: 10.0 + 5.0 * i as f64,
        });
        id += 1;
    }
    jobs
}

fn main() {
    let shards = shards();
    let jobs = job_mix();
    println!(
        "placement: {} jobs over {} heterogeneous shards (policy fifo, \
         restage 2s)\n",
        jobs.len(),
        shards.len()
    );
    println!(
        "{:<14} {:<8} {:>10} {:>7} {:>8} {:>11}  {}",
        "strategy", "mode", "makespan", "moves", "elastic", "regressions", "spread"
    );
    for strategy in [
        PlacementStrategy::RoundRobin,
        PlacementStrategy::LeastLoaded,
        PlacementStrategy::CostBased,
    ] {
        for mode in [RebalanceMode::Queued, RebalanceMode::Elastic] {
            let out = simulate_placement(
                strategy,
                SchedulePolicy::Fifo,
                mode,
                &jobs,
                &shards,
                2.0,
                1_000_000.0,
            );
            assert_eq!(out.unfinished, 0, "sim must drain: {out:?}");
            assert_eq!(
                out.score_regressions, 0,
                "best-score migration must never lose to first-idle-fit"
            );
            let spread: Vec<String> = out
                .per_shard_started
                .iter()
                .enumerate()
                .map(|(i, n)| format!("s{i}:{n}"))
                .collect();
            let label = match strategy {
                PlacementStrategy::RoundRobin => "round-robin",
                PlacementStrategy::LeastLoaded => "least-loaded",
                PlacementStrategy::CostBased => "cost-based",
            };
            println!(
                "{:<14} {:<8} {:>9.1}s {:>7} {:>8} {:>11}  {}",
                label,
                mode.as_str(),
                out.makespan,
                out.queued_migrations,
                out.elastic_migrations,
                out.score_regressions,
                spread.join(" ")
            );
        }
    }
    println!(
        "\nqueued mode can only move jobs that never started; elastic mode \
         checkpoints running jobs off overloaded shards at epoch \
         boundaries (keeping completed epochs) so blocked wide jobs \
         dispatch sooner. Every migration is scored by the ONE placement \
         cost model; regressions counts how often the engine's pick \
         scored worse than first-idle-fit would have — pinned at zero."
    );
}
