//! Shard-router comparison on the deterministic multi-shard cluster
//! simulation (`modak::cluster::simulate_cluster`) — the same engine the
//! least-loaded-vs-round-robin regression test drives, over a bigger,
//! heterogeneous cluster and a skewed job mix.
//!
//! Needs no AOT artifacts: everything is the pure routing + scheduling
//! decision logic, so the numbers are exactly reproducible on any host.
//! Reported per router:
//!
//! * makespan — finish time of the last job,
//! * mean queue wait — arrival to dispatch,
//! * spread — jobs dispatched per shard (round-robin ignores capacity;
//!   least-loaded and perf-aware weight work toward the fat shard).
//!
//! Run: `cargo bench --bench cluster_routing`

use modak::cluster::{simulate_cluster, ClusterSimJob, ShardRouter};
use modak::frameworks::Target;
use modak::scheduler::policy::{NodeState, SchedulePolicy};

/// A heterogeneous 3-shard cluster: fat (2 nodes x 2 slots), medium
/// (1 node x 2 slots), lean (1 node x 1 slot).
fn shards() -> Vec<Vec<NodeState>> {
    let node = |id: usize, slots: usize| NodeState {
        id,
        class: Target::Cpu,
        free_slots: slots,
        total_slots: slots,
    };
    vec![
        vec![node(0, 2), node(1, 2)],
        vec![node(0, 2)],
        vec![node(0, 1)],
    ]
}

/// Skewed mix: a burst of alternating long/short jobs at t=0 (the case
/// that punishes capacity-blind routing), then a steady trickle.
fn job_mix() -> Vec<ClusterSimJob> {
    let mut jobs = Vec::new();
    let mut id = 0;
    for i in 0..18 {
        jobs.push(ClusterSimJob {
            id,
            class: Target::Cpu,
            demand: 1,
            dur: if i % 2 == 0 { 60.0 } else { 4.0 + i as f64 },
            arrive: 0.0,
        });
        id += 1;
    }
    for i in 0..12 {
        jobs.push(ClusterSimJob {
            id,
            class: Target::Cpu,
            demand: 1,
            dur: 9.0,
            arrive: 5.0 + 4.0 * i as f64,
        });
        id += 1;
    }
    jobs
}

fn main() {
    let shards = shards();
    let jobs = job_mix();
    println!(
        "cluster_routing: {} jobs over {} heterogeneous shards (policy fifo)\n",
        jobs.len(),
        shards.len()
    );
    println!(
        "{:<14} {:>10} {:>12} {:>8}  {}",
        "router", "makespan", "mean wait", "undone", "spread"
    );
    for router in [
        ShardRouter::RoundRobin,
        ShardRouter::LeastLoaded,
        ShardRouter::PerfAware,
    ] {
        let out = simulate_cluster(router, SchedulePolicy::Fifo, &jobs, &shards, 100_000.0);
        let waits: Vec<f64> = jobs
            .iter()
            .filter_map(|j| out.started.get(&j.id).map(|(_, t)| t - j.arrive))
            .collect();
        let mean_wait = if waits.is_empty() {
            0.0
        } else {
            waits.iter().sum::<f64>() / waits.len() as f64
        };
        let spread: Vec<String> = out
            .per_shard_started
            .iter()
            .enumerate()
            .map(|(i, n)| format!("s{i}:{n}"))
            .collect();
        println!(
            "{:<14} {:>9.1}s {:>11.1}s {:>8}  {}",
            router.as_str(),
            out.makespan,
            mean_wait,
            out.unfinished,
            spread.join(" ")
        );
    }
    println!(
        "\nround-robin deals jobs blindly; least-loaded balances model-\
         predicted backlog per slot; perf-aware adds the image-staging \
         cost — zero in this sim (no images), so here it matches \
         least-loaded; its edge shows up live when only some shards \
         already hold a job's bundle."
    );
}
