//! Dataset-staging router comparison on the deterministic data-cluster
//! simulation (`modak::data::sim`) — the engine behind the
//! locality-beats-round-robin and warm-rerun regression tests, over a
//! bigger heterogeneous cluster and a larger dataset working set.
//!
//! Needs no AOT artifacts: everything is pure routing + staging + policy
//! decision logic, so the numbers are exactly reproducible on any host.
//! Reported per router, cold then warm (same caches, second pass):
//!
//! * makespan — finish time of the last job (staging extends the first
//!   job that pulls a dataset onto a cold shard),
//! * GB moved — shared-store bytes staged into shard caches,
//! * miss/hit — shard-tier staging events.
//!
//! Run: `cargo bench --bench io_staging`

use modak::cluster::ShardRouter;
use modak::data::sim::{cold_caches, simulate_data_cluster, DataSimJob, ShardCaches};
use modak::frameworks::Target;
use modak::scheduler::policy::{NodeState, SchedulePolicy};

/// A heterogeneous 3-shard cluster: fat (2 nodes x 2 slots), medium
/// (1 node x 2 slots), lean (1 node x 1 slot).
fn shards() -> Vec<Vec<NodeState>> {
    let node = |id: usize, slots: usize| NodeState {
        id,
        class: Target::Cpu,
        free_slots: slots,
        total_slots: slots,
    };
    vec![
        vec![node(0, 2), node(1, 2)],
        vec![node(0, 2)],
        vec![node(0, 1)],
    ]
}

/// Data-heavy mix: 4 datasets (8-40 GB), ~6 jobs per dataset arriving
/// interleaved, compute small next to cold staging — the regime where the
/// router's data-locality term pays or costs the most.
fn job_mix() -> Vec<DataSimJob> {
    let gb = 1_000_000_000u64;
    let sets: [(&str, u64); 4] = [
        ("imagenet-a", 40 * gb),
        ("imagenet-b", 24 * gb),
        ("speech-c", 16 * gb),
        ("logs-d", 8 * gb),
    ];
    (0..24)
        .map(|i| {
            let (name, bytes) = sets[i % sets.len()];
            DataSimJob {
                id: i as u64,
                demand: 1,
                dur: 6.0 + (i % 5) as f64,
                arrive: (i / 8) as f64 * 3.0,
                dataset: Some((format!("data:{name}"), bytes)),
            }
        })
        .collect()
}

fn main() {
    let shards = shards();
    let jobs = job_mix();
    println!(
        "io_staging: {} jobs over {} heterogeneous shards, 4 datasets \
         (policy fifo)\n",
        jobs.len(),
        shards.len()
    );
    println!(
        "{:<14} {:>5} {:>10} {:>9} {:>10} {:>8}",
        "router", "pass", "makespan", "GB moved", "miss/hit", "undone"
    );
    for router in [
        ShardRouter::RoundRobin,
        ShardRouter::LeastLoaded,
        ShardRouter::PerfAware,
    ] {
        let mut caches: ShardCaches = cold_caches(shards.len());
        for pass in ["cold", "warm"] {
            let out = simulate_data_cluster(
                router,
                SchedulePolicy::Fifo,
                &jobs,
                &shards,
                &mut caches,
                1_000_000.0,
            );
            println!(
                "{:<14} {:>5} {:>9.1}s {:>9.1} {:>6}/{:<3} {:>8}",
                router.as_str(),
                pass,
                out.makespan,
                out.bytes_moved as f64 / 1e9,
                out.stage_misses,
                out.stage_hits,
                out.unfinished
            );
        }
    }
    println!(
        "\nround-robin replicates datasets across shards it deals jobs to; \
         perf-aware's data-locality term keeps jobs with their data, so it \
         moves fewer bytes cold and nothing warm. The warm pass reruns the \
         same mix against the caches the cold pass filled — the gap is the \
         tiered cache paying off."
    );
}
