//! Scheduling-policy comparison on a deterministic discrete-event
//! simulation of the paper's testbed shape (3 cpu nodes x 2 slots).
//!
//! Unlike the paper-figure benches this needs no AOT artifacts: it drives
//! `modak::scheduler::policy::simulate` — the same pure engine the live
//! `TorqueServer` consults on every scheduling pass, and the same
//! simulator behind the starvation regression test — with a synthetic
//! heterogeneous job mix. Reported per policy:
//!
//! * makespan — finish time of the last job,
//! * mean queue wait — submission to dispatch,
//! * wide-job wait — how long the 2-slot jobs sat blocked (the starvation
//!   headline: FIFO backfill can hold them indefinitely under a stream of
//!   small jobs; reservation bounds the wait).
//!
//! Run: `cargo bench --bench sched_policies`

use modak::frameworks::Target;
use modak::scheduler::policy::{simulate, NodeState, SchedulePolicy, SimJob};

/// Heterogeneous mix echoing a serve-batch over the dsl/ samples: a burst
/// of mixed short/long 1-slot jobs (predicted runtimes from the trained
/// model), two wide 2-slot jobs submitted early, and a trickle of late
/// small arrivals that plain backfill uses to starve the wide jobs.
fn job_mix() -> Vec<SimJob> {
    let job = |id: u64, demand: usize, dur: f64, arrive: f64| SimJob {
        id,
        class: Target::Cpu,
        demand,
        dur,
        arrive,
    };
    let mut jobs = Vec::new();
    let mut id = 0;
    // burst at t=0: durations cycle long/short the way a mixed DSL dir does
    for i in 0..12 {
        let dur = if i % 3 == 0 { 60.0 } else { 6.0 + i as f64 };
        jobs.push(job(id, 1, dur, 0.0));
        id += 1;
    }
    // two wide jobs shortly after the burst head starts
    for _ in 0..2 {
        jobs.push(job(id, 2, 25.0, 2.0));
        id += 1;
    }
    // steady trickle of small jobs
    for i in 0..10 {
        jobs.push(job(id, 1, 8.0, 10.0 + 6.0 * i as f64));
        id += 1;
    }
    jobs
}

fn main() {
    let nodes: Vec<NodeState> = (0..3)
        .map(|id| NodeState {
            id,
            class: Target::Cpu,
            free_slots: 2,
            total_slots: 2,
        })
        .collect();
    let jobs = job_mix();
    println!(
        "sched_policies: {} jobs ({} wide) on {} nodes x 2 slots\n",
        jobs.len(),
        jobs.iter().filter(|j| j.demand > 1).count(),
        nodes.len()
    );
    println!(
        "{:<13} {:>10} {:>12} {:>12} {:>11}",
        "policy", "makespan", "mean wait", "wide wait", "unfinished"
    );
    for policy in [
        SchedulePolicy::Fifo,
        SchedulePolicy::Sjf,
        SchedulePolicy::Reservation,
    ] {
        let out = simulate(policy, &jobs, &nodes, f64::INFINITY);
        let waits: Vec<(usize, f64)> = jobs
            .iter()
            .filter_map(|j| out.started.get(&j.id).map(|t| (j.demand, t - j.arrive)))
            .collect();
        let mean_wait = if waits.is_empty() {
            0.0
        } else {
            waits.iter().map(|(_, w)| w).sum::<f64>() / waits.len() as f64
        };
        let wide_wait = waits
            .iter()
            .filter(|(d, _)| *d > 1)
            .map(|(_, w)| *w)
            .fold(0.0, f64::max);
        println!(
            "{:<13} {:>9.1}s {:>11.2}s {:>11.2}s {:>11}",
            policy.as_str(),
            out.makespan,
            mean_wait,
            wide_wait,
            out.unfinished
        );
    }
    println!(
        "\nsjf packs short predicted jobs first (mean wait), reservation \
         bounds the wide jobs' wait (starvation); fifo is the PR 1 baseline."
    );
}
