//! The 100k-job scale benchmark: poll-driven vs event-driven scheduler
//! core (`modak::placement::scale`) — ROADMAP item 5's headline numbers.
//!
//! Needs no AOT artifacts: the simulated clock carries the workload, the
//! real wall-clock carries only the cost of deciding, so the comparison is
//! reproducible on any host (absolute times vary with the machine; the
//! poll-vs-event ratio is the point). Both cores make byte-identical
//! placement decisions (asserted), so the schedules agree and only the
//! scheduler's own overhead differs.
//!
//! Run: `cargo bench --bench scale` — prints a table and rewrites
//! `BENCH_scale.json` in the working directory.

use modak::placement::scale::{
    peak_rss_bytes, run_routing_bench, run_scale, CoreMode, ScaleConfig, ScaleOutcome,
};

fn run_mode(mode: CoreMode) -> (ScaleOutcome, u64) {
    let out = run_scale(&ScaleConfig::headline(mode));
    assert_eq!(out.completed, 100_000, "{} sim must drain", mode.as_str());
    // VmHWM is a process-wide high-water mark: sampled after each run, so
    // the first mode's figure is its own and later ones are upper bounds
    (out, peak_rss_bytes())
}

fn json_entry(mode: CoreMode, out: &ScaleOutcome, rss: u64) -> String {
    format!(
        "  \"{}\": {{\n    \"jobs\": {},\n    \"shards\": 64,\n    \
         \"events\": {},\n    \"wall_secs\": {:.4},\n    \
         \"mean_overhead_ms_per_job\": {:.4},\n    \
         \"p50_queue_wait_secs\": {},\n    \
         \"p99_queue_wait_secs\": {},\n    \
         \"rolling_p50_queue_wait_secs\": {},\n    \
         \"rolling_p99_queue_wait_secs\": {},\n    \
         \"p50_overhead_secs\": {},\n    \
         \"p99_overhead_secs\": {},\n    \
         \"makespan_millis\": {},\n    \"peak_queue\": {},\n    \
         \"peak_rss_bytes\": {}\n  }}",
        mode.as_str().replace('-', "_"),
        out.completed,
        out.events,
        out.wall_secs,
        out.mean_overhead_ms_per_job,
        out.p50_queue_wait_secs,
        out.p99_queue_wait_secs,
        out.rolling_p50_queue_wait_secs,
        out.rolling_p99_queue_wait_secs,
        out.p50_overhead_secs,
        out.p99_overhead_secs,
        out.makespan_millis,
        out.peak_queue,
        rss,
    )
}

fn main() {
    println!("scale: 100000 jobs over 64 shards x 32 slots (deterministic sim)\n");
    println!(
        "{:<14} {:>10} {:>10} {:>12} {:>11} {:>12}",
        "core", "events", "wall(s)", "ms/job", "peak queue", "peak rss(MB)"
    );

    // event-driven first so its RSS sample is not inflated by the other
    // core's allocations
    let (event, event_rss) = run_mode(CoreMode::EventDriven);
    let (poll, poll_rss) = run_mode(CoreMode::PollDriven);

    for (mode, out, rss) in [
        (CoreMode::EventDriven, &event, event_rss),
        (CoreMode::PollDriven, &poll, poll_rss),
    ] {
        println!(
            "{:<14} {:>10} {:>10.3} {:>12.4} {:>11} {:>12.1}",
            mode.as_str(),
            out.events,
            out.wall_secs,
            out.mean_overhead_ms_per_job,
            out.peak_queue,
            rss as f64 / (1024.0 * 1024.0),
        );
    }

    // obs histogram percentiles (ISSUE 8): queue wait is simulated time
    // (deterministic, identical across cores); overhead is real time
    println!(
        "\n{:<14} {:>14} {:>14} {:>14} {:>14}",
        "percentiles", "p50 wait(s)", "p99 wait(s)", "p50 ovh(us)", "p99 ovh(us)"
    );
    for (mode, out) in [(CoreMode::EventDriven, &event), (CoreMode::PollDriven, &poll)] {
        println!(
            "{:<14} {:>14.6} {:>14.6} {:>14.2} {:>14.2}",
            mode.as_str(),
            out.p50_queue_wait_secs,
            out.p99_queue_wait_secs,
            out.p50_overhead_secs * 1e6,
            out.p99_overhead_secs * 1e6,
        );
    }
    assert_eq!(
        event.p99_queue_wait_secs, poll.p99_queue_wait_secs,
        "identical schedules must produce identical simulated waits"
    );

    // rolling-window view (PR 9): the same dispatch stream through the
    // live plane's SnapshotRing, restricted to the closing 60 s of sim
    // time — steady-state tail vs the whole-run percentiles above
    println!(
        "rolling 60s    {:>14.6} {:>14.6}           (sim-clock window)",
        event.rolling_p50_queue_wait_secs, event.rolling_p99_queue_wait_secs,
    );
    assert_eq!(
        event.rolling_p99_queue_wait_secs, poll.rolling_p99_queue_wait_secs,
        "identical schedules must agree in the rolling window too"
    );

    // the two cores must have made identical decisions: same schedule
    assert_eq!(event.makespan_millis, poll.makespan_millis);
    assert_eq!(event.events, poll.events);
    assert_eq!(event.peak_queue, poll.peak_queue);
    assert!(
        event.wall_secs < poll.wall_secs,
        "event-driven core must beat the poll-driven sweep \
         ({:.3}s vs {:.3}s)",
        event.wall_secs,
        poll.wall_secs
    );

    let speedup = poll.wall_secs / event.wall_secs.max(1e-9);
    println!(
        "\nidentical schedules (makespan {} ms, {} events); event-driven \
         core is {speedup:.1}x faster on scheduler overhead",
        event.makespan_millis, event.events
    );

    // live-cluster routing throughput (PR 10): the same decision stream
    // scored through the incremental placement ledger vs the pre-ledger
    // full-snapshot path, on a real (quiescent) ClusterScheduler
    let routing = run_routing_bench(32, 2_000);
    println!(
        "\n{:<14} {:>10} {:>16} {:>16}",
        "routing", "routes", "ledger(rt/s)", "snapshot(rt/s)"
    );
    println!(
        "{:<14} {:>10} {:>16.0} {:>16.0}",
        "live cluster",
        routing.routes,
        routing.ledger_routes_per_sec,
        routing.snapshot_routes_per_sec,
    );
    assert!(
        routing.decisions_match,
        "ledger and snapshot scoring must make identical routing decisions"
    );
    assert!(
        routing.ledger_routes_per_sec > routing.snapshot_routes_per_sec,
        "ledger routing must beat the snapshot path ({:.0} vs {:.0} routes/sec)",
        routing.ledger_routes_per_sec,
        routing.snapshot_routes_per_sec
    );
    let routing_ratio = routing.ledger_routes_per_sec / routing.snapshot_routes_per_sec.max(1e-9);
    println!("ledger routing is {routing_ratio:.1}x the snapshot path (identical decisions)");

    let json = format!(
        "{{\n{},\n{},\n  \"routing\": {{\n    \"shards\": 32,\n    \
         \"routes\": {},\n    \"ledger_routes_per_sec\": {:.0},\n    \
         \"snapshot_routes_per_sec\": {:.0},\n    \
         \"ledger_over_snapshot\": {:.2},\n    \
         \"decisions_match\": {}\n  }},\n  \"speedup\": {:.2},\n  \
         \"note\": \"regenerate with: cargo bench --bench scale\"\n}}\n",
        json_entry(CoreMode::EventDriven, &event, event_rss),
        json_entry(CoreMode::PollDriven, &poll, poll_rss),
        routing.routes,
        routing.ledger_routes_per_sec,
        routing.snapshot_routes_per_sec,
        routing_ratio,
        routing.decisions_match,
        speedup,
    );
    match std::fs::write("BENCH_scale.json", &json) {
        Ok(()) => println!("wrote BENCH_scale.json"),
        Err(e) => eprintln!("scale: writing BENCH_scale.json failed: {e}"),
    }
}
