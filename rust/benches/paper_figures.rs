//! `cargo bench` entrypoint: regenerates every table and figure of the
//! paper's evaluation (Table I, Fig 3, Fig 4 L/R, Fig 5 L/R) through the
//! full stack and prints the reports with shape checks.
//!
//! criterion is not in the vendored crate set; this is a harness=false
//! bench binary. Select a subset with
//! `cargo bench --bench paper_figures -- fig3 fig5_left`.

use modak::figures::{FigureConfig, Harness};
use modak::perfmodel::PerfModel;
use modak::registry::RegistryHandle;
use modak::runtime::Manifest;
use modak::util::timer::Stopwatch;

fn main() {
    // cargo passes --bench; ignore flags, keep figure ids
    let want: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    let all = [
        "table1",
        "fig3",
        "fig4_left",
        "fig4_right",
        "fig5_left",
        "fig5_right",
    ];
    let selected: Vec<&str> = if want.is_empty() {
        all.to_vec()
    } else {
        all.iter().copied().filter(|id| want.iter().any(|w| w == id)).collect()
    };

    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("paper_figures bench skipped (run `make artifacts`): {e}");
            return;
        }
    };
    let registry = RegistryHandle::open("images", &manifest, 1);
    let mut model = PerfModel::open("perf_history.json").expect("perf history");
    let mut harness = Harness::new(&manifest, &registry);
    harness.model = Some(&mut model);

    let mut failed = Vec::new();
    for id in selected {
        let sw = Stopwatch::start();
        let report = match id {
            "table1" => Ok(harness.table1()),
            "fig3" => harness.fig3(&FigureConfig::mnist()),
            "fig4_left" => harness.fig4_left(&FigureConfig::mnist()),
            "fig4_right" => harness.fig4_right(&FigureConfig::resnet()),
            "fig5_left" => harness.fig5_left(&FigureConfig::mnist_compilers()),
            "fig5_right" => harness.fig5_right(&FigureConfig::resnet()),
            _ => unreachable!(),
        };
        match report {
            Ok(rep) => {
                println!("{}", rep.render());
                println!("  [bench harness: {id} regenerated in {:.1}s]\n", sw.elapsed_secs());
                if !rep.all_checks_hold() {
                    failed.push(id);
                }
            }
            Err(e) => {
                eprintln!("{id} FAILED: {e:#}");
                failed.push(id);
            }
        }
    }
    model.save().expect("saving perf history");
    if model.is_trained() {
        println!(
            "performance model: {} observations, r2 = {:.3}",
            model.history.len(),
            model.r2
        );
    }
    if !failed.is_empty() {
        eprintln!("shape checks failed for: {failed:?}");
        std::process::exit(1);
    }
}
