//! MODAK coordinator integration: DSL -> optimiser -> registry/builder ->
//! scheduler -> containerised training, over real artifacts.
//!
//! Skips when `artifacts/` is absent (each test returns early with a
//! note instead of erroring, so `cargo test -q` stays green on a fresh
//! clone without AOT artifacts). Serialized (XLA compiles are
//! memory-hungry on this host).

use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

use modak::cluster::ShardRouter;
use modak::dsl::Optimisation;
use modak::optimiser::{plan_deployment, Optimiser};
use modak::perfmodel::{Features, PerfModel, Record};
use modak::registry::RegistryHandle;
use modak::runtime::Manifest;
use modak::scheduler::{JobScript, JobState, Payload, Resources, SchedulePolicy, TorqueServer};
use modak::service::{BatchRequest, DeploymentService, ServiceConfig};
use modak::trainer::TrainConfig;

fn serial() -> MutexGuard<'static, ()> {
    static GUARD: OnceLock<Mutex<()>> = OnceLock::new();
    GUARD
        .get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping (run `make artifacts`): {e}");
            None
        }
    }
}

fn store(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join("modak_it_store").join(name);
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn listing1_dsl_plans_and_runs_on_testbed() {
    let _g = serial();
    let Some(m) = manifest() else { return };
    let dsl = Optimisation::parse(modak::dsl::LISTING_1).unwrap();
    let registry = RegistryHandle::open(store("listing1"), &m, 2);
    let model = PerfModel::new();
    let cfg = TrainConfig {
        epochs: 2,
        steps_per_epoch: 2,
        seed: 0,
    };
    let optimiser = Optimiser::new(&registry, &model, &m);
    let plan = optimiser.plan(&dsl, &cfg).unwrap();

    // Listing 1 asks for tensorflow + xla on an Nvidia target:
    assert_eq!(plan.profile.framework, "tensorflow");
    assert_eq!(plan.profile.graph_compiler, Some("xla"));
    assert_eq!(plan.profile.target, modak::frameworks::Target::GpuSim);
    // version 1.1 is not packaged; MODAK resolves to a supported version
    assert!(plan.notes.iter().any(|n| n.contains("1.1")));
    assert!(plan.script.payload.nv);
    assert_eq!(plan.script.resources.gpus, 1);

    // the plan's script runs end-to-end on the testbed
    let mut server = TorqueServer::boot(0, 1);
    server.register_image(&plan.profile.image_tag(), plan.image.dir.clone());
    let id = server.qsub(plan.script.clone()).unwrap();
    server.wait(id).unwrap();
    let rec = server.job(id).unwrap();
    let JobState::Completed { run, .. } = &rec.state else {
        panic!("job failed: {:?}", rec.state)
    };
    assert_eq!(run.workload, "resnet50s");
    assert!(run.report.final_loss().is_finite());
    assert!(rec.queue_wait_secs.is_some());
}

/// A model trained on a synthetic calibration sweep that makes
/// tuned-kernel builds look much cheaper. History spans BOTH workloads:
/// with mnist-only rows the dispatches and gbytes features are perfectly
/// correlated across profiles and the normal equations go singular —
/// exactly why real calibration sweeps diverse containers.
fn calibrated_model(m: &Manifest) -> PerfModel {
    let mut model = PerfModel::new();
    let profiles = modak::frameworks::all_profiles();
    // observations across several run configs (vary epochs/steps so the
    // feature matrix is well-conditioned, like real benchmark history)
    for p in &profiles {
        let wl = m.workload(p.workload).unwrap();
        for (epochs, steps) in [(1, 2), (2, 3), (3, 4), (2, 8)] {
            let feats = Features::derive(
                p,
                wl,
                &TrainConfig {
                    epochs,
                    steps_per_epoch: steps,
                    seed: 0,
                },
            );
            // planted cost: heavily punish the kernel_steps feature so
            // fused_ref (src) wins
            let secs = 1.0
                + 0.1 * feats.steps
                + 0.2 * feats.dispatches
                + 0.5 * feats.gbytes
                + 1.0 * feats.compiles
                + 3.0 * feats.kernel_steps;
            model.observe(Record {
                image: p.image_tag(),
                workload: p.workload.into(),
                features: feats,
                measured_secs: secs,
            });
        }
    }
    model
}

#[test]
fn optimiser_uses_trained_model_to_rank() {
    let _g = serial();
    let Some(m) = manifest() else { return };
    let cfg = TrainConfig {
        epochs: 2,
        steps_per_epoch: 2,
        seed: 0,
    };
    let model = calibrated_model(&m);
    assert!(model.is_trained());

    let dsl = Optimisation::parse(
        r#"{"app_type": "ai_training", "enable_opt_build": false,
            "workload": "mnist_cnn",
            "ai_training": {"tensorflow": {"version": "2.1"}}}"#,
    )
    .unwrap();
    let registry = RegistryHandle::open(store("rank"), &m, 2);
    let optimiser = Optimiser::new(&registry, &model, &m);
    let plan = optimiser.plan(&dsl, &cfg).unwrap();
    assert!(plan.predicted_secs.is_some());
    // model must have picked the lowest-predicted candidate: fused_ref (src)
    assert_eq!(plan.profile.variant, "fused_ref", "{:?}", plan.notes);
}

#[test]
fn scheduler_runs_two_containers_back_to_back() {
    let _g = serial();
    let Some(m) = manifest() else { return };
    let registry = RegistryHandle::open(store("two"), &m, 1);
    let tag = "tensorflow:2.1-cpu-src";
    let image = registry.ensure_built(tag).unwrap();

    let mut server = TorqueServer::boot(1, 0);
    server.register_image(tag, image.dir.clone());
    let script = |seed: i32| JobScript {
        name: format!("j{seed}"),
        queue: "batch".into(),
        resources: Resources {
            nodes: 1,
            gpus: 0,
            slots: 1,
            walltime: Duration::from_secs(600),
        },
        payload: Payload {
            image: tag.into(),
            epochs: 1,
            steps_per_epoch: 2,
            lr: 0.05,
            seed,
            nv: false,
            dataset: None,
        },
        predicted_secs: None,
    };
    let a = server.qsub(script(1)).unwrap();
    let b = server.qsub(script(2)).unwrap();
    // single 1-slot cpu node: never more than one running
    assert!(server.busy_nodes().len() <= 1);
    server.wait_all().unwrap();
    for id in [a, b] {
        assert_eq!(server.job(id).unwrap().state.code(), 'C');
    }
    assert_eq!(server.finish_order(), &[a, b]);
}

#[test]
fn walltime_violation_kills_job() {
    let _g = serial();
    let Some(m) = manifest() else { return };
    let registry = RegistryHandle::open(store("walltime"), &m, 1);
    let tag = "tensorflow:2.1-cpu-src";
    let image = registry.ensure_built(tag).unwrap();
    let mut server = TorqueServer::boot(1, 0);
    server.register_image(tag, image.dir.clone());
    let script = JobScript {
        name: "tiny-walltime".into(),
        queue: "batch".into(),
        resources: Resources {
            nodes: 1,
            gpus: 0,
            slots: 1,
            walltime: Duration::from_millis(1),
        },
        payload: Payload {
            image: tag.into(),
            epochs: 1,
            steps_per_epoch: 1,
            lr: 0.05,
            seed: 0,
            nv: false,
            dataset: None,
        },
        predicted_secs: None,
    };
    let id = server.qsub(script).unwrap();
    server.wait(id).unwrap();
    let rec = server.job(id).unwrap();
    let JobState::Failed { error, .. } = &rec.state else {
        panic!("expected walltime kill, got {:?}", rec.state)
    };
    assert!(error.contains("walltime"), "{error}");
    // the node watchdog killed it at the boundary: the slot is free again
    assert!(server.busy_nodes().is_empty());
}

#[test]
fn gpu_image_without_nv_fails_inside_scheduler() {
    let _g = serial();
    let Some(m) = manifest() else { return };
    let registry = RegistryHandle::open(store("nv"), &m, 1);
    let tag = "tensorflow:2.1-gpu-src";
    let image = registry.ensure_built(tag).unwrap();
    assert!(image.gpu);
    let mut server = TorqueServer::boot(0, 1);
    server.register_image(tag, image.dir.clone());
    let script = JobScript {
        name: "no-nv".into(),
        queue: "batch".into(),
        resources: Resources {
            nodes: 1,
            gpus: 1,
            slots: 1,
            walltime: Duration::from_secs(600),
        },
        payload: Payload {
            image: tag.into(),
            epochs: 1,
            steps_per_epoch: 1,
            lr: 0.05,
            seed: 0,
            nv: false, // forgot --nv
            dataset: None,
        },
        predicted_secs: None,
    };
    let id = server.qsub(script).unwrap();
    server.wait(id).unwrap();
    let JobState::Failed { error, .. } = &server.job(id).unwrap().state else {
        panic!("expected --nv failure")
    };
    assert!(error.contains("--nv"), "{error}");
}

#[test]
fn prebuilt_images_are_reused_not_rebuilt() {
    let _g = serial();
    let Some(m) = manifest() else { return };
    let dir = store("reuse");
    let registry = RegistryHandle::open(&dir, &m, 1);
    let tag = "pytorch:1.14-cpu-hub";
    let first = registry.ensure_built(tag).unwrap();
    // a fresh registry handle over the same store finds the prebuilt bundle
    let registry2 = RegistryHandle::open(&dir, &m, 2);
    assert!(registry2.with(|r| r.get(tag).unwrap().bundle.is_some()));
    let second = registry2.ensure_built(tag).unwrap();
    assert_eq!(first.digest, second.digest);
    // the prebuilt bundle counted as a cache hit, not a build
    let stats = registry2.build_stats();
    assert_eq!(stats.builds, 0);
    assert_eq!(stats.cache_hits, 1);
}

#[test]
fn concurrent_ensure_built_same_profile_builds_once() {
    let _g = serial();
    let Some(m) = manifest() else { return };
    let registry = RegistryHandle::open(store("concurrent_build"), &m, 4);
    let tag = "pytorch:1.14-cpu-hub";
    let threads: Vec<_> = (0..4)
        .map(|_| {
            let r = registry.clone();
            let tag = tag.to_string();
            std::thread::spawn(move || r.ensure_built(&tag).unwrap())
        })
        .collect();
    let images: Vec<_> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    for img in &images[1..] {
        assert_eq!(img.digest, images[0].digest);
        assert_eq!(img.dir, images[0].dir);
    }
    let stats = registry.build_stats();
    assert_eq!(stats.builds, 1, "{stats:?}");
    assert_eq!(stats.cache_hits, 3, "{stats:?}");
}

/// Acceptance: the legacy one-shot path and the batch service produce
/// identical plans for the same DSL input (one shared code path).
#[test]
fn legacy_and_batch_paths_produce_identical_plans() {
    let _g = serial();
    let Some(m) = manifest() else { return };
    let registry = RegistryHandle::open(store("one_path"), &m, 2);
    let model = PerfModel::new();
    let cfg = TrainConfig {
        epochs: 2,
        steps_per_epoch: 3,
        seed: 7,
    };
    let dsl_text = r#"{"app_type": "ai_training", "enable_opt_build": true,
        "workload": "mnist_cnn",
        "ai_training": {"pytorch": {"version": "1.14"}}}"#;
    let dsl = Optimisation::parse(dsl_text).unwrap();

    // legacy path: direct plan_deployment (what `modak optimise` resolves to)
    let catalog = modak::data::DatasetCatalog::builtin();
    let legacy = plan_deployment(&registry, &model, &m, &catalog, &dsl, &cfg).unwrap();

    // batch path: through the service work queue, same registry handle
    let service = DeploymentService::with_registry(
        registry.clone(),
        m.clone(),
        PerfModel::new(),
        &ServiceConfig::default(),
    );
    let mut handles = service.submit_many(
        vec![BatchRequest {
            label: "same-dsl".into(),
            dsl,
        }],
        &cfg,
        false,
    );
    let outcome = handles[0].wait();
    let batch = outcome.plan.as_ref().unwrap();

    assert_eq!(batch.profile.image_tag(), legacy.profile.image_tag());
    assert_eq!(batch.image.digest, legacy.image.digest);
    assert_eq!(batch.script, legacy.script);
    assert_eq!(batch.predicted_secs, legacy.predicted_secs);
}

/// Acceptance: a heterogeneous batch overlaps jobs on the slotted testbed
/// and duplicate profiles hit the build cache.
#[test]
fn batch_submission_overlaps_jobs_and_hits_build_cache() {
    let _g = serial();
    let Some(m) = manifest() else { return };
    let service = DeploymentService::new(
        store("batch"),
        m.clone(),
        PerfModel::new(),
        &ServiceConfig {
            cpu_nodes: 2,
            gpu_nodes: 0,
            slots_per_node: 2,
            max_build_workers: 2,
            planner_workers: 4,
            ..ServiceConfig::default()
        },
    );
    let cfg = TrainConfig {
        epochs: 1,
        steps_per_epoch: 2,
        seed: 0,
    };
    let dsl = |fw: &str, ver: &str| {
        Optimisation::parse(&format!(
            r#"{{"app_type": "ai_training", "workload": "mnist_cnn",
                "ai_training": {{"{fw}": {{"version": "{ver}"}}}}}}"#
        ))
        .unwrap()
    };
    let reqs = vec![
        BatchRequest { label: "tf-a".into(), dsl: dsl("tensorflow", "2.1") },
        BatchRequest { label: "tf-b".into(), dsl: dsl("tensorflow", "2.1") }, // same profile
        BatchRequest { label: "pt".into(), dsl: dsl("pytorch", "1.14") },
        BatchRequest { label: "mx".into(), dsl: dsl("mxnet", "2.0") },
    ];
    let report = service.run_batch(reqs, &cfg, |_| {});
    eprintln!("{}", report.render());
    assert_eq!(report.completed(), 4, "{report:?}");
    // two identical tf requests -> at least one digest-keyed cache hit
    assert!(report.build_stats.cache_hits > 0, "{:?}", report.build_stats);
    // 2 nodes x 2 slots: the batch must actually have overlapped
    assert!(report.peak_running >= 2, "{report:?}");
    assert!(report.makespan_secs > 0.0);
    assert!(report.serial_sum_secs > 0.0);
}

/// Tentpole acceptance: a heterogeneous 4-shard cluster behind the same
/// serve-batch code path completes a mixed cpu/gpu batch routed
/// `perf-aware`, and the report's per-shard stats sum to the batch totals.
#[test]
fn multi_shard_batch_completes_with_per_shard_stats() {
    let _g = serial();
    let Some(m) = manifest() else { return };
    let service = DeploymentService::new(
        store("cluster4"),
        m.clone(),
        PerfModel::new(),
        &ServiceConfig {
            cpu_nodes: 1,
            gpu_nodes: 1,
            slots_per_node: 2,
            shards: 4,
            router: ShardRouter::PerfAware,
            ..ServiceConfig::default()
        },
    );
    assert_eq!(service.cluster().shard_count(), 4);
    assert_eq!(service.cluster().router(), ShardRouter::PerfAware);
    let cfg = TrainConfig {
        epochs: 1,
        steps_per_epoch: 2,
        seed: 0,
    };
    let cpu_dsl = |fw: &str, ver: &str| {
        Optimisation::parse(&format!(
            r#"{{"app_type": "ai_training", "workload": "mnist_cnn",
                "ai_training": {{"{fw}": {{"version": "{ver}"}}}}}}"#
        ))
        .unwrap()
    };
    let gpu_dsl = Optimisation::parse(
        r#"{"app_type": "ai_training",
            "opt_build": {"cpu_type": "x86", "acc_type": "nvidia"},
            "ai_training": {"tensorflow": {"version": "2.1"}}}"#,
    )
    .unwrap();
    let reqs = vec![
        BatchRequest { label: "tf-a".into(), dsl: cpu_dsl("tensorflow", "2.1") },
        BatchRequest { label: "tf-b".into(), dsl: cpu_dsl("tensorflow", "2.1") },
        BatchRequest { label: "pt".into(), dsl: cpu_dsl("pytorch", "1.14") },
        BatchRequest { label: "mx".into(), dsl: cpu_dsl("mxnet", "2.0") },
        BatchRequest { label: "tf-gpu".into(), dsl: gpu_dsl },
    ];
    let n = reqs.len();
    let report = service.run_batch(reqs, &cfg, |_| {});
    eprintln!("{}", report.render());
    assert_eq!(report.completed(), n, "{report:?}");
    let cluster = report.cluster.as_ref().expect("cluster section");
    assert_eq!(cluster.shards.len(), 4);
    assert_eq!(cluster.router, "perf-aware");
    // per-shard stats sum to the batch totals
    assert_eq!(cluster.shards.iter().map(|s| s.jobs).sum::<usize>(), n);
    assert_eq!(
        cluster.shards.iter().map(|s| s.completed).sum::<usize>(),
        report.completed()
    );
    let busy: f64 = cluster.shards.iter().map(|s| s.busy_secs).sum();
    assert!(
        (busy - report.serial_sum_secs).abs() < 1e-6,
        "shard busy sum {busy} != serial sum {}",
        report.serial_sum_secs
    );
    // every dispatched job knows which shard ran it
    for j in &report.jobs {
        assert!(j.shard.is_some(), "{j:?}");
        assert_eq!(j.state, 'C', "{j:?}");
    }
    // the gpu request landed on a gpu-capable shard (even shards only)
    let gpu_shard = report.jobs.last().unwrap().shard.unwrap();
    assert!(gpu_shard % 2 == 0, "gpu job on shard {gpu_shard}");
    // image distribution: bundles were staged into shard-local stores
    // (at least one miss; duplicate profiles on one shard become hits)
    assert!(cluster.staging_totals.misses >= 1, "{:?}", cluster.staging_totals);
    assert!(cluster.staging_totals.simulated_secs > 0.0);
    let rendered = report.render();
    assert!(rendered.contains("cluster: 4 shards"), "{rendered}");
    assert!(rendered.contains("shard 0:"), "{rendered}");
}

/// Tentpole acceptance: the data pipeline end to end. A DSL request with a
/// `dataset:` block plans with per-tier IO estimates, stages the dataset
/// shard- and node-local, trains through the double-buffered prefetcher
/// (IO overlapped with compute), and the batch report carries the dataset
/// staging counters.
#[test]
fn dataset_request_stages_and_trains_with_io_overlap() {
    let _g = serial();
    let Some(m) = manifest() else { return };
    let service = DeploymentService::new(
        store("data_pipeline"),
        m.clone(),
        PerfModel::new(),
        &ServiceConfig {
            cpu_nodes: 2,
            gpu_nodes: 0,
            slots_per_node: 1,
            ..ServiceConfig::default()
        },
    );
    let cfg = TrainConfig {
        epochs: 1,
        steps_per_epoch: 2,
        seed: 0,
    };
    let with_data = Optimisation::parse(
        r#"{"app_type": "ai_training", "workload": "mnist_cnn",
            "dataset": {"name": "mnist-60k"},
            "ai_training": {"tensorflow": {"version": "2.1"}}}"#,
    )
    .unwrap();
    let plain = Optimisation::parse(
        r#"{"app_type": "ai_training", "workload": "mnist_cnn",
            "ai_training": {"pytorch": {"version": "1.14"}}}"#,
    )
    .unwrap();
    let report = service.run_batch(
        vec![
            BatchRequest { label: "with-data".into(), dsl: with_data },
            BatchRequest { label: "plain".into(), dsl: plain },
        ],
        &cfg,
        |_| {},
    );
    eprintln!("{}", report.render());
    assert_eq!(report.completed(), 2, "{report:?}");
    // the data job simulated IO through the prefetcher; the plain job
    // stayed on the synthetic in-memory path
    let data_job = &report.jobs[0];
    assert!(data_job.io_secs.unwrap_or(0.0) > 0.0, "{data_job:?}");
    assert!(report.jobs[1].io_secs.is_none(), "{:?}", report.jobs[1]);
    // staging counters: one shard-tier and one node-tier placement
    let cluster = report.cluster.as_ref().unwrap();
    let d = &cluster.data_totals;
    assert_eq!(d.shard_misses, 1, "{d:?}");
    assert_eq!(d.node_misses, 1, "{d:?}");
    assert!(d.bytes_moved > 0, "{d:?}");
    assert!(report.render().contains("data staging:"), "render shows data");
    // warm rerun of the same request: the shard tier hits, bytes move only
    // for tiers not yet warm on whichever node runs it
    let rerun = Optimisation::parse(
        r#"{"app_type": "ai_training", "workload": "mnist_cnn",
            "dataset": {"name": "mnist-60k"},
            "ai_training": {"tensorflow": {"version": "2.1"}}}"#,
    )
    .unwrap();
    let bytes_before = service.cluster().data_totals().bytes_moved;
    let report2 = service.run_batch(
        vec![BatchRequest { label: "warm".into(), dsl: rerun }],
        &cfg,
        |_| {},
    );
    assert_eq!(report2.completed(), 1, "{report2:?}");
    let d = service.cluster().data_totals();
    assert!(d.shard_hits >= 1, "warm shard tier: {d:?}");
    // warm rerun moved strictly fewer new bytes than the cold first run
    let new_bytes = d.bytes_moved - bytes_before;
    assert!(
        new_bytes < bytes_before,
        "warm rerun moved {new_bytes} vs cold {bytes_before}"
    );
}

/// Acceptance: perf-model-driven co-scheduling closes the loop. A trained
/// model's predictions ride into the scheduler (sjf packing), the report
/// carries per-job predicted-vs-measured error, and every completed job's
/// measured wall time is fed back into the model (online refit).
#[test]
fn sjf_batch_reports_prediction_error_and_feeds_model_back() {
    let _g = serial();
    let Some(m) = manifest() else { return };
    let model = calibrated_model(&m);
    assert!(model.is_trained());
    let history_before = model.history.len();
    let service = DeploymentService::new(
        store("sjf_feedback"),
        m.clone(),
        model,
        &ServiceConfig {
            cpu_nodes: 2,
            gpu_nodes: 0,
            slots_per_node: 1,
            policy: SchedulePolicy::Sjf,
            ..ServiceConfig::default()
        },
    );
    assert_eq!(service.with_server(|srv| srv.policy()), SchedulePolicy::Sjf);
    let cfg = TrainConfig {
        epochs: 1,
        steps_per_epoch: 2,
        seed: 0,
    };
    let dsl = |fw: &str, ver: &str| {
        Optimisation::parse(&format!(
            r#"{{"app_type": "ai_training", "workload": "mnist_cnn",
                "ai_training": {{"{fw}": {{"version": "{ver}"}}}}}}"#
        ))
        .unwrap()
    };
    let reqs = vec![
        BatchRequest { label: "tf".into(), dsl: dsl("tensorflow", "2.1") },
        BatchRequest { label: "pt".into(), dsl: dsl("pytorch", "1.14") },
        BatchRequest { label: "mx".into(), dsl: dsl("mxnet", "2.0") },
    ];
    let report = service.run_batch(reqs, &cfg, |_| {});
    eprintln!("{}", report.render());
    assert_eq!(report.completed(), 3, "{report:?}");
    // a trained model predicted every plan, and the report shows the
    // predicted-vs-measured split per job
    for j in &report.jobs {
        assert!(j.predicted_secs.is_some(), "{j:?}");
        assert!(j.pct_error().is_some(), "{j:?}");
    }
    assert!(report.mean_abs_pct_error().is_some());
    assert!(report.model_r2.is_some());
    // online feedback: one new observation per completed job, refit live
    service.with_model(|pm| {
        assert_eq!(pm.history.len(), history_before + 3);
        assert!(pm.is_trained());
    });
}
