//! MODAK coordinator integration: DSL -> optimiser -> registry/builder ->
//! scheduler -> containerised training, over real artifacts.
//!
//! Skips when `artifacts/` is absent. Serialized (XLA compiles are
//! memory-hungry on this host).

use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

use modak::dsl::Optimisation;
use modak::optimiser::Optimiser;
use modak::perfmodel::{Features, PerfModel, Record};
use modak::registry::Registry;
use modak::runtime::Manifest;
use modak::scheduler::{JobScript, JobState, Payload, Resources, TorqueServer};
use modak::trainer::TrainConfig;

fn serial() -> MutexGuard<'static, ()> {
    static GUARD: OnceLock<Mutex<()>> = OnceLock::new();
    GUARD
        .get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping (run `make artifacts`): {e}");
            None
        }
    }
}

fn store(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join("modak_it_store").join(name);
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn listing1_dsl_plans_and_runs_on_testbed() {
    let _g = serial();
    let Some(m) = manifest() else { return };
    let dsl = Optimisation::parse(modak::dsl::LISTING_1).unwrap();
    let mut registry = Registry::open(store("listing1"));
    let model = PerfModel::new();
    let cfg = TrainConfig {
        epochs: 2,
        steps_per_epoch: 2,
        seed: 0,
    };
    let mut optimiser = Optimiser::new(&mut registry, &model, &m);
    let plan = optimiser.plan(&dsl, &cfg).unwrap();

    // Listing 1 asks for tensorflow + xla on an Nvidia target:
    assert_eq!(plan.profile.framework, "tensorflow");
    assert_eq!(plan.profile.graph_compiler, Some("xla"));
    assert_eq!(plan.profile.target, modak::frameworks::Target::GpuSim);
    // version 1.1 is not packaged; MODAK resolves to a supported version
    assert!(plan.notes.iter().any(|n| n.contains("1.1")));
    assert!(plan.script.payload.nv);
    assert_eq!(plan.script.resources.gpus, 1);

    // the plan's script runs end-to-end on the testbed
    let mut server = TorqueServer::boot(0, 1);
    server.register_image(&plan.profile.image_tag(), plan.image.dir.clone());
    let id = server.qsub(plan.script.clone()).unwrap();
    server.wait(id).unwrap();
    let rec = server.job(id).unwrap();
    let JobState::Completed { run, .. } = &rec.state else {
        panic!("job failed: {:?}", rec.state)
    };
    assert_eq!(run.workload, "resnet50s");
    assert!(run.report.final_loss().is_finite());
}

#[test]
fn optimiser_uses_trained_model_to_rank() {
    let _g = serial();
    let Some(m) = manifest() else { return };
    let cfg = TrainConfig {
        epochs: 2,
        steps_per_epoch: 2,
        seed: 0,
    };
    // train a model that makes tuned-kernel builds look much cheaper.
    // History spans BOTH workloads: with mnist-only rows the dispatches
    // and gbytes features are perfectly correlated across profiles and the
    // normal equations go singular — exactly why real calibration sweeps
    // diverse containers.
    let mut model = PerfModel::new();
    let mut registry = Registry::open(store("rank"));
    let profiles: Vec<_> = registry.entries().map(|e| e.profile.clone()).collect();
    // observations across several run configs (vary epochs/steps so the
    // feature matrix is well-conditioned, like real benchmark history)
    for p in &profiles {
        let wl = m.workload(p.workload).unwrap();
        for (epochs, steps) in [(1, 2), (2, 3), (3, 4), (2, 8)] {
            let feats = Features::derive(
                p,
                wl,
                &TrainConfig {
                    epochs,
                    steps_per_epoch: steps,
                    seed: 0,
                },
            );
            // planted cost: heavily punish the kernel_steps feature so
            // fused_ref (src) wins
            let secs = 1.0
                + 0.1 * feats.steps
                + 0.2 * feats.dispatches
                + 0.5 * feats.gbytes
                + 1.0 * feats.compiles
                + 3.0 * feats.kernel_steps;
            model.observe(Record {
                image: p.image_tag(),
                workload: p.workload.into(),
                features: feats,
                measured_secs: secs,
            });
        }
    }
    assert!(model.is_trained());

    let dsl = Optimisation::parse(
        r#"{"app_type": "ai_training", "enable_opt_build": false,
            "workload": "mnist_cnn",
            "ai_training": {"tensorflow": {"version": "2.1"}}}"#,
    )
    .unwrap();
    let mut optimiser = Optimiser::new(&mut registry, &model, &m);
    let plan = optimiser.plan(&dsl, &cfg).unwrap();
    assert!(plan.predicted_secs.is_some());
    // model must have picked the lowest-predicted candidate: fused_ref (src)
    assert_eq!(plan.profile.variant, "fused_ref", "{:?}", plan.notes);
}

#[test]
fn scheduler_runs_two_containers_back_to_back() {
    let _g = serial();
    let Some(m) = manifest() else { return };
    let mut registry = Registry::open(store("two"));
    let tag = "tensorflow:2.1-cpu-src";
    let image = registry.ensure_built(tag, &m).unwrap();

    let mut server = TorqueServer::boot(1, 0);
    server.register_image(tag, image.dir.clone());
    let script = |seed: i32| JobScript {
        name: format!("j{seed}"),
        queue: "batch".into(),
        resources: Resources {
            nodes: 1,
            gpus: 0,
            walltime: Duration::from_secs(600),
        },
        payload: Payload {
            image: tag.into(),
            epochs: 1,
            steps_per_epoch: 2,
            lr: 0.05,
            seed,
            nv: false,
        },
    };
    let a = server.qsub(script(1)).unwrap();
    let b = server.qsub(script(2)).unwrap();
    // single cpu node: never more than one running
    assert!(server.busy_nodes().len() <= 1);
    server.wait_all().unwrap();
    for id in [a, b] {
        assert_eq!(server.job(id).unwrap().state.code(), 'C');
    }
}

#[test]
fn walltime_violation_kills_job() {
    let _g = serial();
    let Some(m) = manifest() else { return };
    let mut registry = Registry::open(store("walltime"));
    let tag = "tensorflow:2.1-cpu-src";
    let image = registry.ensure_built(tag, &m).unwrap();
    let mut server = TorqueServer::boot(1, 0);
    server.register_image(tag, image.dir.clone());
    let script = JobScript {
        name: "tiny-walltime".into(),
        queue: "batch".into(),
        resources: Resources {
            nodes: 1,
            gpus: 0,
            walltime: Duration::from_millis(1),
        },
        payload: Payload {
            image: tag.into(),
            epochs: 1,
            steps_per_epoch: 1,
            lr: 0.05,
            seed: 0,
            nv: false,
        },
    };
    let id = server.qsub(script).unwrap();
    server.wait(id).unwrap();
    let rec = server.job(id).unwrap();
    let JobState::Failed { error, .. } = &rec.state else {
        panic!("expected walltime kill, got {:?}", rec.state)
    };
    assert!(error.contains("walltime"), "{error}");
}

#[test]
fn gpu_image_without_nv_fails_inside_scheduler() {
    let _g = serial();
    let Some(m) = manifest() else { return };
    let mut registry = Registry::open(store("nv"));
    let tag = "tensorflow:2.1-gpu-src";
    let image = registry.ensure_built(tag, &m).unwrap();
    assert!(image.gpu);
    let mut server = TorqueServer::boot(0, 1);
    server.register_image(tag, image.dir.clone());
    let script = JobScript {
        name: "no-nv".into(),
        queue: "batch".into(),
        resources: Resources {
            nodes: 1,
            gpus: 1,
            walltime: Duration::from_secs(600),
        },
        payload: Payload {
            image: tag.into(),
            epochs: 1,
            steps_per_epoch: 1,
            lr: 0.05,
            seed: 0,
            nv: false, // forgot --nv
        },
    };
    let id = server.qsub(script).unwrap();
    server.wait(id).unwrap();
    let JobState::Failed { error, .. } = &server.job(id).unwrap().state else {
        panic!("expected --nv failure")
    };
    assert!(error.contains("--nv"), "{error}");
}

#[test]
fn prebuilt_images_are_reused_not_rebuilt() {
    let _g = serial();
    let Some(m) = manifest() else { return };
    let dir = store("reuse");
    let mut registry = Registry::open(&dir);
    let tag = "pytorch:1.14-cpu-hub";
    let first = registry.ensure_built(tag, &m).unwrap();
    // a fresh registry over the same store finds the prebuilt bundle
    let mut registry2 = Registry::open(&dir);
    assert!(registry2.get(tag).unwrap().bundle.is_some());
    let second = registry2.ensure_built(tag, &m).unwrap();
    assert_eq!(first.digest, second.digest);
}
