//! Integration: real artifacts through the PJRT runtime + executor.
//!
//! Requires `make artifacts`. Skips (with a note) when artifacts/ is absent
//! so `cargo test` stays green on a fresh clone.

use std::sync::{Mutex, MutexGuard, OnceLock};

use modak::executor::{ExecPolicy, TrainSession};
use modak::runtime::{Engine, HostTensor, Manifest};
use modak::trainer::data::Dataset;

/// XLA CPU compilation of the larger artifacts is memory-hungry; running
/// integration tests concurrently can OOM-crash the process. Serialize.
fn serial() -> MutexGuard<'static, ()> {
    static GUARD: OnceLock<Mutex<()>> = OnceLock::new();
    GUARD
        .get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping integration test (run `make artifacts`): {e}");
            None
        }
    }
}

fn batch(m: &Manifest, wl: &str, seed: u64) -> (HostTensor, HostTensor) {
    Dataset::for_workload(m.workload(wl).unwrap(), seed).next_batch()
}

#[test]
fn manifest_loads_and_validates() {
    let _guard = serial();
    let Some(m) = manifest() else { return };
    assert!(m.workloads.contains_key("mnist_cnn"));
    assert!(m.workloads.contains_key("resnet50s"));
    assert_eq!(m.workload("mnist_cnn").unwrap().param_count, 1_199_882);
}

#[test]
fn init_artifact_is_deterministic() {
    let _guard = serial();
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let s1 = TrainSession::new(&engine, &m, "mnist_cnn", "fused_ref", ExecPolicy::host(), 7, 0.05)
        .unwrap();
    let s2 = TrainSession::new(&engine, &m, "mnist_cnn", "fused_ref", ExecPolicy::host(), 7, 0.05)
        .unwrap();
    for (a, b) in s1.params().iter().zip(s2.params()) {
        assert_eq!(a, b);
    }
}

/// The central honesty invariant: every variant x policy computes the same
/// training trajectory (same losses, same params) from the same seed, so
/// benchmarked differences are pure mechanics.
#[test]
fn all_mnist_variants_agree_numerically() {
    let _guard = serial();
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let combos: &[(&str, ExecPolicy)] = &[
        ("fused_ref", ExecPolicy::host()),
        ("fused_generic", ExecPolicy::host()),
        ("fused_pallas", ExecPolicy::host()),
        ("fused_ref", ExecPolicy::recompiling()),
        ("staged_ref", ExecPolicy::host()),
        ("staged_ref", ExecPolicy::device()),
        ("staged_generic", ExecPolicy::device()),
        ("staged_naive", ExecPolicy::host()),
    ];
    let mut traces: Vec<(String, Vec<f32>)> = Vec::new();
    for (variant, policy) in combos {
        let mut sess =
            TrainSession::new(&engine, &m, "mnist_cnn", variant, *policy, 3, 0.05).unwrap();
        let mut data = Dataset::for_workload(&sess.workload, 11);
        let mut losses = Vec::new();
        for _ in 0..3 {
            let (x, y) = data.next_batch();
            losses.push(sess.step(&x, &y).unwrap());
        }
        traces.push((format!("{variant}/{policy:?}"), losses));
    }
    let (ref name0, ref base) = traces[0];
    for (name, losses) in &traces[1..] {
        for (i, (a, b)) in base.iter().zip(losses).enumerate() {
            assert!(
                (a - b).abs() < 2e-2 * a.abs().max(1.0),
                "step {i}: {name0}={a} vs {name}={b}"
            );
        }
    }
}

#[test]
fn mnist_loss_decreases_over_training() {
    let _guard = serial();
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let mut sess =
        TrainSession::new(&engine, &m, "mnist_cnn", "fused_ref", ExecPolicy::host(), 0, 0.05)
            .unwrap();
    let mut data = Dataset::for_workload(&sess.workload, 5);
    let mut first = 0.0;
    let mut last = 0.0;
    for i in 0..15 {
        let (x, y) = data.next_batch();
        let loss = sess.step(&x, &y).unwrap();
        if i == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(
        last < 0.6 * first,
        "loss did not decrease: first {first}, last {last}"
    );
}

#[test]
fn resnet_threestage_matches_fused() {
    let _guard = serial();
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let mut fused =
        TrainSession::new(&engine, &m, "resnet50s", "fused_ref", ExecPolicy::host(), 1, 0.01)
            .unwrap();
    let mut three = TrainSession::new(
        &engine,
        &m,
        "resnet50s",
        "threestage_ref",
        ExecPolicy::host(),
        1,
        0.01,
    )
    .unwrap();
    let (x, y) = batch(&m, "resnet50s", 2);
    let lf = fused.step(&x, &y).unwrap();
    let lt = three.step(&x, &y).unwrap();
    assert!((lf - lt).abs() < 1e-3 * lf.abs().max(1.0), "{lf} vs {lt}");
    for (a, b) in fused.params().iter().zip(three.params()) {
        let av = a.as_f32().unwrap();
        let bv = b.as_f32().unwrap();
        for (x1, x2) in av.iter().zip(bv) {
            assert!((x1 - x2).abs() < 5e-3, "param drift {x1} vs {x2}");
        }
    }
}

#[test]
fn exec_stats_count_mechanics() {
    let _guard = serial();
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();

    // fused: 1 dispatch per step
    let mut fused =
        TrainSession::new(&engine, &m, "mnist_cnn", "fused_ref", ExecPolicy::host(), 0, 0.05)
            .unwrap();
    let d0 = fused.stats.dispatches;
    let (x, y) = batch(&m, "mnist_cnn", 1);
    fused.step(&x, &y).unwrap();
    assert_eq!(fused.stats.dispatches - d0, 1);

    // staged mnist: 3 fwd + 4 bwd + 1 update = 8 dispatches per step
    let mut staged =
        TrainSession::new(&engine, &m, "mnist_cnn", "staged_ref", ExecPolicy::host(), 0, 0.05)
            .unwrap();
    let d0 = staged.stats.dispatches;
    staged.step(&x, &y).unwrap();
    assert_eq!(staged.stats.dispatches - d0, 8);

    // staged moves more bytes across the host than fused
    assert!(staged.stats.bytes_h2d > fused.stats.bytes_h2d);

    // recompiling policy compiles at every epoch boundary
    let mut xla =
        TrainSession::new(&engine, &m, "mnist_cnn", "fused_ref", ExecPolicy::recompiling(), 0, 0.05)
            .unwrap();
    let c0 = xla.stats.compiles;
    xla.begin_epoch().unwrap();
    xla.begin_epoch().unwrap();
    assert_eq!(xla.stats.compiles - c0, 2);
    assert!(xla.stats.compile_secs > 0.0);
}

#[test]
fn bad_variant_and_bad_batch_are_errors() {
    let _guard = serial();
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    assert!(
        TrainSession::new(&engine, &m, "mnist_cnn", "nope", ExecPolicy::host(), 0, 0.05).is_err()
    );
    let mut sess =
        TrainSession::new(&engine, &m, "mnist_cnn", "fused_ref", ExecPolicy::host(), 0, 0.05)
            .unwrap();
    let x = HostTensor::f32(vec![1, 2, 2, 1], vec![0.0; 4]);
    let y = HostTensor::s32(vec![1], vec![0]);
    assert!(sess.step(&x, &y).is_err());
}
